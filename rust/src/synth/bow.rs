//! Synthetic bag-of-words corpus generator (Medline stand-in).
//!
//! Each document draws its length from a Poisson around the target token
//! count, then samples tokens from a Zipfian vocabulary; repeated draws of
//! the same token accumulate as counts (exactly how a real BoW matrix is
//! built). Labels come from a sparse ground-truth logistic model over the
//! generated features ([`super::labels`]), so a trainable signal exists and
//! accuracy/F1 can be reported against a known model.

use crate::data::{CsrMatrix, SparseDataset};
use crate::util::Rng;

use super::labels::{GroundTruth, LabelSpec};
use super::zipf::Zipf;

/// Specification of a synthetic corpus. Defaults mirror the paper's
/// Medline statistics at 1/50 scale; use `BowSpec::medline_full()` for the
/// full n = 1,000,000 corpus.
#[derive(Debug, Clone)]
pub struct BowSpec {
    /// Number of documents (paper: 1,000,000).
    pub n_examples: usize,
    /// Vocabulary size d (paper: 260,941).
    pub n_features: usize,
    /// Target mean number of *distinct* tokens per document (paper: 88.54).
    pub avg_nnz: f64,
    /// Zipf exponent for token frequencies (~1.07 for English text).
    pub zipf_exponent: f64,
    /// Ground-truth label model specification.
    pub labels: LabelSpec,
}

impl Default for BowSpec {
    fn default() -> Self {
        BowSpec {
            n_examples: 20_000,
            n_features: 260_941,
            avg_nnz: 88.54,
            zipf_exponent: 1.07,
            labels: LabelSpec::default(),
        }
    }
}

impl BowSpec {
    /// The paper's full-scale Medline shape (n = 1,000,000).
    pub fn medline_full() -> BowSpec {
        BowSpec { n_examples: 1_000_000, ..Default::default() }
    }

    /// A small corpus for unit tests and quickstarts.
    pub fn tiny() -> BowSpec {
        BowSpec { n_examples: 500, n_features: 2_000, avg_nnz: 20.0, ..Default::default() }
    }
}

/// Mean number of tokens to draw so the *distinct* count hits `avg_nnz`.
///
/// Drawing L Zipfian tokens yields fewer than L distinct types because
/// high-frequency words repeat. We correct with a short fixed-point
/// search on the expected-distinct curve, estimated by simulation on a
/// few hundred documents (cheap, done once per generate call).
fn calibrate_token_count(spec: &BowSpec, rng: &mut Rng) -> f64 {
    let zipf = Zipf::new(spec.n_features as u64, spec.zipf_exponent);
    let mut tokens = spec.avg_nnz; // start: distinct == tokens
    let trial_docs = 200;
    let mut scratch: Vec<u64> = Vec::new();
    for _ in 0..8 {
        let mut distinct_sum = 0usize;
        for _ in 0..trial_docs {
            let len = rng.poisson(tokens).max(1);
            scratch.clear();
            for _ in 0..len {
                scratch.push(zipf.sample(rng));
            }
            scratch.sort_unstable();
            scratch.dedup();
            distinct_sum += scratch.len();
        }
        let mean_distinct = distinct_sum as f64 / trial_docs as f64;
        if (mean_distinct - spec.avg_nnz).abs() / spec.avg_nnz < 0.02 {
            break;
        }
        tokens *= spec.avg_nnz / mean_distinct.max(1.0);
    }
    tokens
}

/// Generate a corpus per `spec`, deterministically from `seed`.
pub fn generate(spec: &BowSpec, seed: u64) -> SparseDataset {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(spec.n_features as u64, spec.zipf_exponent);
    let tokens_per_doc = calibrate_token_count(spec, &mut rng);
    let truth = GroundTruth::generate(&spec.labels, spec.n_features, &mut rng);

    let mut truth = truth;
    let mut x = CsrMatrix::empty(spec.n_features);
    let mut entries: Vec<(u32, f32)> = Vec::with_capacity((tokens_per_doc * 1.5) as usize + 4);

    for _ in 0..spec.n_examples {
        let len = rng.poisson(tokens_per_doc).max(1);
        entries.clear();
        for _ in 0..len {
            // Zipf ranks are 1-based; feature ids 0-based.
            let j = (zipf.sample(&mut rng) - 1) as u32;
            entries.push((j, 1.0));
        }
        let row = entries.clone();
        x.push_row(row); // push_row sorts + merges duplicates into counts
    }

    // Calibrate the teacher bias so the positive rate hits the target:
    // bias = -quantile(logits, 1 - target).
    let sample_n = x.n_rows().min(2_000);
    let mut sample_logits: Vec<f64> = (0..sample_n).map(|r| truth.logit(&x, r)).collect();
    // total_cmp: a NaN logit (a degenerate spec) must not panic the
    // generator mid-sort (the PR 6 `partial_cmp` bug class).
    sample_logits.sort_unstable_by(f64::total_cmp);
    let q = (1.0 - spec.labels.target_positive_rate).clamp(0.0, 1.0);
    let idx = ((q * (sample_n.saturating_sub(1)) as f64).round() as usize).min(sample_n - 1);
    truth.bias = -sample_logits[idx] as f32;

    let labels: Vec<f32> = (0..x.n_rows()).map(|r| truth.label(&x, r, &mut rng)).collect();
    SparseDataset::new(x, labels).expect("generator invariant")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_target_statistics() {
        let spec = BowSpec {
            n_examples: 2_000,
            n_features: 50_000,
            avg_nnz: 60.0,
            ..Default::default()
        };
        let data = generate(&spec, 42);
        let stats = data.stats();
        assert_eq!(stats.n_examples, 2_000);
        assert_eq!(stats.n_features, 50_000);
        // distinct-token calibration should land within 10% of target
        assert!(
            (stats.avg_nnz - 60.0).abs() < 6.0,
            "avg_nnz = {}",
            stats.avg_nnz
        );
        data.x().validate().unwrap();
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = BowSpec::tiny();
        let a = generate(&spec, 7);
        let b = generate(&spec, 7);
        assert_eq!(a, b);
        let c = generate(&spec, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn labels_are_binary_and_balanced_enough() {
        let data = generate(&BowSpec::tiny(), 3);
        let stats = data.stats();
        assert!(data.labels().iter().all(|&y| y == 0.0 || y == 1.0));
        assert!(
            stats.positive_rate > 0.15 && stats.positive_rate < 0.85,
            "positive rate {}",
            stats.positive_rate
        );
    }

    #[test]
    fn frequencies_follow_power_law() {
        let spec = BowSpec {
            n_examples: 3_000,
            n_features: 10_000,
            avg_nnz: 40.0,
            ..Default::default()
        };
        let data = generate(&spec, 11);
        let mut df = data.x().column_frequencies();
        df.sort_unstable_by(|a, b| b.cmp(a));
        // Head should vastly out-weigh the tail.
        assert!(df[0] > 50 * df[999].max(1), "df[0]={} df[999]={}", df[0], df[999]);
        // A long zero tail exists (most of the vocabulary unused).
        let zeros = df.iter().filter(|&&c| c == 0).count();
        assert!(zeros > 1000);
    }
}
