//! [`SparseModel`]: scoring over the model's nonzero support only.
//!
//! After ℓ1 training most weights are exactly zero, so the dense
//! blocked kernel spends most of its weight-gather bandwidth loading
//! zeros. This module stores the model as sorted `(indices, weights)`
//! nonzero pairs — the exact shape of the compact `LZMC` artifact
//! ([`crate::model::compact`]) — and scores with a **sorted merge-join**
//! over example × model nonzeros: both index lists are ascending, so
//! one forward pointer over the model support finds every match without
//! touching the zeros.
//!
//! ## Bitwise equality with the dense blocked kernel
//!
//! [`sparse_block_partials`] walks the row exactly like
//! [`super::block_partials`] — same blocks opened and emitted, same
//! ascending accumulation order — and skips only terms whose model
//! weight is exactly zero. Each skipped dense term is `v × (±0.0)`,
//! i.e. `±0.0`; the dense accumulator starts at `+0.0` and under IEEE
//! 754 round-to-nearest a sum starting at `+0.0` can never become
//! `-0.0` (`+0.0 + -0.0 = +0.0`, and exact cancellation `x + (-x)`
//! rounds to `+0.0`), and `x + ±0.0 == x` **bitwise** for every `x`
//! other than `-0.0`. So dropping those terms leaves every partial —
//! and therefore [`super::fold_score`] — bit-for-bit unchanged. The one
//! caveat: a non-finite row value against a zero weight would give
//! `NaN` densely (`inf × 0`) and be skipped here; CSR rows come from
//! parsers that only produce finite values, and the property suite pins
//! the equality with `.to_bits()` over randomized models and rows.
//!
//! The same argument makes the compacted shard scorers
//! ([`super::ShardedModel`], [`crate::net::ShardServer`]) bitwise-equal
//! to their dense predecessors: they emit identical block-partial
//! lists, and the fold order is unchanged.

use crate::data::RowView;
use crate::loss::Loss;
use crate::model::LinearModel;

use super::{fold_score, Predictor, SCORE_BLOCK};

/// Append `row`'s non-empty `(block id, partial sum)` pairs to `out`,
/// accumulating only the features present in the sorted model support
/// `indices`/`weights` (absolute feature indices; parallel arrays).
///
/// Emits a pair for every block the **row** touches — including blocks
/// where no index matches, whose partial is then `+0.0` — so the output
/// block list is identical to [`super::block_partials`] over the dense
/// vector, and the partials are bitwise-equal (see the module docs).
/// `O(row.nnz + matched support span)`: the forward pointer `p` only
/// ever advances, so scoring a row costs the merge-join, never `O(d)`.
pub fn sparse_block_partials(
    row: RowView<'_>,
    indices: &[u32],
    weights: &[f64],
    out: &mut Vec<(u32, f64)>,
) {
    debug_assert_eq!(indices.len(), weights.len());
    let mut cur = 0u32;
    let mut acc = 0.0f64;
    let mut open = false;
    let mut p = 0usize;
    for (j, v) in row.iter() {
        let b = j / SCORE_BLOCK;
        if open && b != cur {
            out.push((cur, acc));
            acc = 0.0;
        }
        cur = b;
        open = true;
        while p < indices.len() && indices[p] < j {
            p += 1;
        }
        if p < indices.len() && indices[p] == j {
            acc += f64::from(v) * weights[p];
        }
    }
    if open {
        out.push((cur, acc));
    }
}

/// The model as sorted nonzero `(index, weight)` pairs plus bias — the
/// in-memory dual of the compact `LZMC` artifact, scored by the
/// merge-join kernel. `f64` scores are bitwise-equal to the dense
/// blocked kernel (module docs); memory and weight-gather traffic are
/// O(nnz), not O(d).
pub struct SparseModel {
    dim: usize,
    indices: Vec<u32>,
    weights: Vec<f64>,
    bias: f64,
    loss: Loss,
    version: u64,
}

impl SparseModel {
    /// Extract the nonzero support of `model`; `version` is reported
    /// verbatim.
    pub fn from_model(model: &LinearModel, version: u64) -> SparseModel {
        let mut indices = Vec::new();
        let mut weights = Vec::new();
        for (j, &w) in model.weights.iter().enumerate() {
            if w != 0.0 {
                indices.push(j as u32);
                weights.push(w);
            }
        }
        SparseModel {
            dim: model.dim(),
            indices,
            weights,
            bias: model.bias,
            loss: model.loss,
            version,
        }
    }

    /// Number of stored nonzero weights.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }
}

impl Predictor for SparseModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        let mut partials = Vec::new();
        sparse_block_partials(row, &self.indices, &self.weights, &mut partials);
        fold_score(self.bias, &partials)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predict::{block_partials, blocked_score};
    use crate::util::Rng;

    fn random_model(dim: usize, density: f64, seed: u64) -> LinearModel {
        let mut m = LinearModel::zeros(dim, Loss::Logistic);
        let mut rng = Rng::new(seed);
        for w in m.weights.iter_mut() {
            if rng.bool(density) {
                *w = rng.normal();
            }
        }
        m.bias = rng.normal();
        m
    }

    fn random_row(dim: usize, nnz: usize, rng: &mut Rng) -> (Vec<u32>, Vec<f32>) {
        let idx = rng.sample_distinct(dim, nnz.min(dim));
        idx.into_iter().map(|j| (j as u32, rng.normal() as f32)).unzip()
    }

    #[test]
    fn partials_match_dense_bitwise_including_blocks() {
        let dim = 3 * SCORE_BLOCK as usize + 17;
        let mut rng = Rng::new(5);
        for seed in 0..20u64 {
            let m = random_model(dim, 0.02, seed);
            let sm = SparseModel::from_model(&m, 0);
            let (indices, values) = random_row(dim, 150, &mut rng);
            let row = RowView { indices: &indices, values: &values };
            let mut dense = Vec::new();
            block_partials(row, &m.weights, 0, &mut dense);
            let mut sparse = Vec::new();
            sparse_block_partials(row, &sm.indices, &sm.weights, &mut sparse);
            assert_eq!(dense.len(), sparse.len(), "same blocks emitted");
            for (a, b) in dense.iter().zip(&sparse) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "block {} partial", a.0);
            }
        }
    }

    #[test]
    fn scores_match_dense_blocked_kernel_bitwise() {
        let dim = 2 * SCORE_BLOCK as usize + 5;
        let mut rng = Rng::new(9);
        for seed in 0..20u64 {
            let m = random_model(dim, 0.05, seed);
            let sm = SparseModel::from_model(&m, 3);
            for nnz in [0usize, 1, 7, 120] {
                let (indices, values) = random_row(dim, nnz, &mut rng);
                let row = RowView { indices: &indices, values: &values };
                let dense = blocked_score(m.bias, row, &m.weights);
                assert_eq!(sm.score(row).to_bits(), dense.to_bits());
            }
        }
    }

    #[test]
    fn empty_support_scores_bias_for_any_row() {
        let m = LinearModel::zeros(100, Loss::Squared);
        let sm = SparseModel::from_model(&m, 0);
        assert_eq!(sm.nnz(), 0);
        let indices = [3u32, 50];
        let values = [1.0f32, -2.0];
        let row = RowView { indices: &indices, values: &values };
        assert_eq!(sm.score(row).to_bits(), m.bias.to_bits());
    }

    #[test]
    fn reports_model_shape() {
        let mut m = LinearModel::zeros(64, Loss::Hinge);
        m.weights[10] = 1.0;
        m.weights[63] = -2.0;
        let sm = SparseModel::from_model(&m, 11);
        assert_eq!(sm.dim(), 64);
        assert_eq!(sm.nnz(), 2);
        assert_eq!(sm.version(), 11);
        assert_eq!(sm.loss(), Loss::Hinge);
    }
}
