//! The serving-side prediction API: the [`Predictor`] trait and its
//! implementations.
//!
//! Training produces a [`LinearModel`]; *serving* needs an abstraction
//! over the ways that model can be scored at request time:
//!
//! * [`LinearModel`] itself — the native in-process scorer (wrapped in
//!   [`Versioned`] when the server needs reload version tracking);
//! * [`SparseModel`] — the model held as sorted nonzero
//!   `(index, weight)` pairs (the in-memory dual of the compact `LZMC`
//!   artifact, [`crate::model::compact`]), scored by a sorted
//!   merge-join over example × model nonzeros that is bitwise-equal to
//!   the dense blocked kernel — see [`sparse`];
//! * [`ShardedModel`] — the weight vector partitioned by feature range
//!   across N persistent worker threads, the serving dual of the
//!   example-sharded training engine in [`crate::train::parallel`]
//!   (each worker holds only its range's nonzeros and runs the
//!   merge-join kernel);
//! * [`ArtifactBatcher`] — batch scoring through the AOT `predict`
//!   artifact via [`crate::runtime`] (requires the `pjrt` feature at
//!   runtime; the stub runtime's `load` errors and the batcher is never
//!   constructed).
//!
//! ## The canonical blocked score
//!
//! Floating-point addition is not associative, so naively splitting a
//! dot product across shards would change the result with the shard
//! count. This module instead *defines* the serving score with a fixed
//! reduction structure: per-feature products are accumulated
//! sequentially inside [`SCORE_BLOCK`]-wide feature ranges ("blocks"),
//! and the non-empty block partials are folded into the bias in
//! ascending block order ([`fold_score`]). Shard boundaries always fall
//! on block boundaries, and merging shards concatenates their ordered
//! block-partial lists — an associative operation — so **every
//! implementation produces bitwise-identical scores for any shard
//! count** (asserted for shard counts {1, 2, 7} by the test suite).
//!
//! The blocked score differs from the fully-sequential
//! [`LinearModel::score`] (which the trainers' hot paths use and whose
//! rounding the lazy ≡ dense equivalence suite pins down) by at most a
//! few ulps, only when a row spans multiple blocks.

pub mod artifact;
pub mod sharded;
pub mod sparse;

pub use artifact::ArtifactBatcher;
pub use sharded::ShardedModel;
pub use sparse::{sparse_block_partials, SparseModel};

use crate::sync::Arc;

use crate::data::{CsrMatrix, RowView};
use crate::loss::Loss;
use crate::model::LinearModel;

/// Feature-range width of one reduction block of the canonical score.
///
/// Shard boundaries are always multiples of this, so within-block
/// accumulation never crosses a shard.
pub const SCORE_BLOCK: u32 = 4096;

/// The canonical serving score: bias + blocked dot product.
///
/// `weights` is indexed by the row's global feature indices. Defined as
/// [`block_partials`] + [`fold_score`] so there is exactly **one** copy
/// of the rounding chain the bitwise sharding contract depends on.
pub fn blocked_score(bias: f64, row: RowView<'_>, weights: &[f64]) -> f64 {
    let mut partials = Vec::new();
    block_partials(row, weights, 0, &mut partials);
    fold_score(bias, &partials)
}

/// Append `row`'s non-empty `(block id, partial sum)` pairs, in ascending
/// block order, to `out`.
///
/// `weights[0]` holds the weight of global feature `base` (shard workers
/// pass their range offset; whole-vector callers pass 0). Within a block
/// the accumulation order is ascending feature index — exactly the
/// rounding chain [`blocked_score`] uses, so folding the pairs with
/// [`fold_score`] reproduces it bitwise.
pub fn block_partials(row: RowView<'_>, weights: &[f64], base: u32, out: &mut Vec<(u32, f64)>) {
    let mut cur = 0u32;
    let mut acc = 0.0f64;
    let mut open = false;
    for (j, v) in row.iter() {
        let b = j / SCORE_BLOCK;
        if open && b != cur {
            out.push((cur, acc));
            acc = 0.0;
        }
        cur = b;
        open = true;
        acc += f64::from(v) * weights[(j - base) as usize];
    }
    if open {
        out.push((cur, acc));
    }
}

/// Fold block partials (ascending block order) into the bias — the single
/// rounding chain every [`Predictor`] implementation shares.
pub fn fold_score(bias: f64, partials: &[(u32, f64)]) -> f64 {
    let mut z = bias;
    for &(_, p) in partials {
        z += p;
    }
    z
}

/// The opt-in `f32` fast-path score: `bias + w·x` with the weights
/// already quantized to `f32` (CSR values are `f32` natively, so the
/// products stay in one precision end to end), written as an explicit
/// 4-wide chunked loop with four independent accumulator lanes — the
/// shape the autovectorizer lifts into SIMD (the gather of
/// `weights[j]` is the remaining serial step; the multiplies and adds
/// vectorize).
///
/// This is **not** the canonical blocked reduction: lanes replace
/// blocks, so scores differ from [`blocked_score`] within `f32`
/// rounding (≈1e-6 relative) and the bitwise sharding contract does not
/// cover it. It exists for [`F32Model`], the serving fast path measured
/// by the `serve_throughput` bench; the `f64` path stays the default.
pub fn blocked_score_f32(bias: f64, row: RowView<'_>, weights: &[f32]) -> f64 {
    let mut acc = [0.0f32; 4];
    let mut idx = row.indices.chunks_exact(4);
    let mut val = row.values.chunks_exact(4);
    for (ji, vi) in (&mut idx).zip(&mut val) {
        // Four independent lanes: no cross-lane dependency per chunk.
        for l in 0..4 {
            acc[l] += vi[l] * weights[ji[l] as usize];
        }
    }
    let mut tail = 0.0f32;
    for (&j, &v) in idx.remainder().iter().zip(val.remainder().iter()) {
        tail += v * weights[j as usize];
    }
    bias + f64::from((acc[0] + acc[1]) + (acc[2] + acc[3]) + tail)
}

/// The serving fast path: one upfront `f64 → f32` quantization of the
/// weight vector, then every score runs the 4-wide `f32` kernel
/// ([`blocked_score_f32`]). Opt-in (`serve --fast-f32`): predictions
/// agree with the `f64` predictors to `f32` rounding, not bitwise, so
/// the canonical scorers stay the default. Unsharded — the kernel's
/// whole point is that one thread's dot product gets cheaper.
pub struct F32Model {
    weights: Vec<f32>,
    bias: f64,
    loss: Loss,
    version: u64,
}

impl F32Model {
    /// Quantize `model`'s weights once; `version` is reported verbatim.
    pub fn from_model(model: &LinearModel, version: u64) -> F32Model {
        F32Model {
            weights: model.weights.iter().map(|&w| w as f32).collect(),
            bias: model.bias,
            loss: model.loss,
            version,
        }
    }
}

impl Predictor for F32Model {
    fn dim(&self) -> usize {
        self.weights.len()
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        blocked_score_f32(self.bias, row, &self.weights)
    }
}

/// A scoring engine the prediction service can serve from.
///
/// Implementations must be shareable across the server's connection
/// workers (`Send + Sync`); the server holds the current predictor in an
/// `Arc<RwLock<Arc<dyn Predictor>>>` slot so a `reload` can hot-swap it
/// without dropping connections.
///
/// Rows must uphold the [`RowView`] invariant — **strictly increasing
/// column indices** below [`Predictor::dim`]. Every in-tree producer
/// ([`CsrMatrix`], the serve protocol parser) guarantees both halves;
/// [`ShardedModel`] additionally `debug_assert`s them, since its range
/// split binary-searches each row. Violations are a contract breach with
/// impl-defined behavior: the native impl panics on an out-of-range
/// index where a release-build sharded impl silently ignores it.
pub trait Predictor: Send + Sync {
    /// Nominal feature dimensionality (requests index below this).
    fn dim(&self) -> usize;

    /// The loss used to map raw scores to predictions.
    fn loss(&self) -> Loss;

    /// Monotonically increasing model version (bumped on hot reload;
    /// freshly trained / directly constructed predictors report 0).
    fn version(&self) -> u64;

    /// Raw score `z = w·x + b` under the canonical blocked reduction.
    fn score(&self, row: RowView<'_>) -> f64;

    /// Raw scores for a batch of rows.
    fn score_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        rows.iter().map(|&r| self.score(r)).collect()
    }

    /// Prediction in label units (probability for logistic).
    fn predict(&self, row: RowView<'_>) -> f64 {
        self.loss().predict(self.score(row))
    }

    /// Predictions in label units for a batch of rows.
    ///
    /// Implementations with a genuine batch path ([`ArtifactBatcher`])
    /// override this; the default maps the loss over [`Predictor::score_batch`].
    fn predict_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        let loss = self.loss();
        self.score_batch(rows).into_iter().map(|z| loss.predict(z)).collect()
    }

    /// Raw scores for every row of a CSR matrix.
    fn score_matrix(&self, x: &CsrMatrix) -> Vec<f64> {
        let rows: Vec<RowView<'_>> = x.rows().collect();
        self.score_batch(&rows)
    }

    /// Fallible [`Predictor::score_batch`]. In-process predictors cannot
    /// fail, so the default wraps the infallible path; predictors with a
    /// remote dependency ([`crate::net::RemoteShardModel`]) override
    /// this to surface transport/staleness errors. The serve request
    /// path calls the `try_` variants so an upstream failure becomes an
    /// `err` reply instead of a NaN score.
    fn try_score_batch(&self, rows: &[RowView<'_>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.score_batch(rows))
    }

    /// Fallible [`Predictor::predict_batch`]; see
    /// [`Predictor::try_score_batch`]. The default delegates to
    /// `predict_batch` so implementations with a genuine batch path
    /// (like [`ArtifactBatcher`]) keep their override.
    fn try_predict_batch(&self, rows: &[RowView<'_>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.predict_batch(rows))
    }
}

/// The native in-process scorer.
///
/// Note: the trait methods use the canonical *blocked* score so that
/// [`ShardedModel`] is bitwise-interchangeable with it; the inherent
/// [`LinearModel::score`] keeps the trainers' fully-sequential rounding.
/// The two agree to within a few ulps.
impl Predictor for LinearModel {
    fn dim(&self) -> usize {
        self.weights.len()
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn version(&self) -> u64 {
        0
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        blocked_score(self.bias, row, &self.weights)
    }
}

/// Attaches a reload version to any predictor (the server wraps the
/// unsharded [`LinearModel`] in this so `stats` can report the version).
pub struct Versioned<P> {
    inner: P,
    version: u64,
}

impl<P: Predictor> Versioned<P> {
    /// Wrap `inner` with an explicit version.
    pub fn new(inner: P, version: u64) -> Versioned<P> {
        Versioned { inner, version }
    }

    /// Unwrap.
    pub fn into_inner(self) -> P {
        self.inner
    }
}

impl<P: Predictor> Predictor for Versioned<P> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn loss(&self) -> Loss {
        self.inner.loss()
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        self.inner.score(row)
    }

    fn score_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        self.inner.score_batch(rows)
    }

    fn predict_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        self.inner.predict_batch(rows)
    }

    fn try_score_batch(&self, rows: &[RowView<'_>]) -> anyhow::Result<Vec<f64>> {
        self.inner.try_score_batch(rows)
    }

    fn try_predict_batch(&self, rows: &[RowView<'_>]) -> anyhow::Result<Vec<f64>> {
        self.inner.try_predict_batch(rows)
    }
}

/// Build the serving predictor for `model`: in-process for `shards <= 1`,
/// otherwise a feature-sharded worker pool. `version` is what
/// [`Predictor::version`] reports (the server bumps it on each reload).
pub fn build(model: LinearModel, shards: usize, version: u64) -> Arc<dyn Predictor> {
    if shards <= 1 {
        Arc::new(Versioned::new(model, version))
    } else {
        Arc::new(ShardedModel::spawn(&model, shards, version))
    }
}

/// [`build`] for the opt-in `f32` fast path: quantize once, serve from
/// [`F32Model`]. The kernel is single-threaded by design, so a shard
/// request is ignored with a note — never silently.
pub fn build_f32(model: LinearModel, shards: usize, version: u64) -> Arc<dyn Predictor> {
    if shards > 1 {
        eprintln!("predict: the f32 fast path is unsharded; ignoring shards={shards}");
    }
    Arc::new(F32Model::from_model(&model, version))
}

/// [`build`] for the sparse merge-join path: serve from the model's
/// nonzero support only ([`SparseModel`], `serve --sparse`). Scores are
/// bitwise-identical to [`build`]'s (see [`sparse`]); memory and
/// weight-gather traffic drop from O(d) to O(nnz). For `shards > 1` the
/// sharded pool already holds only its ranges' nonzeros, so the request
/// degrades to [`build`] with a note rather than silently.
pub fn build_sparse(model: LinearModel, shards: usize, version: u64) -> Arc<dyn Predictor> {
    if shards > 1 {
        eprintln!(
            "predict: sharded workers already hold compact nonzero ranges; \
             serving sharded at shards={shards}"
        );
        return build(model, shards, version);
    }
    Arc::new(SparseModel::from_model(&model, version))
}

/// Like [`build`], but prefer batch scoring through the AOT `predict`
/// artifact (from [`crate::runtime::Runtime::default_dir`]). Falls back
/// to [`build`] — with the reason on stderr — when the artifacts or the
/// `pjrt` runtime are unavailable, or the model's loss doesn't match.
pub fn build_with_artifact(model: LinearModel, shards: usize, version: u64) -> Arc<dyn Predictor> {
    let dir = crate::runtime::Runtime::default_dir();
    match ArtifactBatcher::load(&dir, &model, version) {
        Ok(batcher) => {
            if shards > 1 {
                eprintln!("predict: artifact batcher is unsharded; ignoring shards={shards}");
            }
            Arc::new(batcher)
        }
        Err(e) => {
            eprintln!("predict: artifact batcher unavailable ({e:#}); serving natively");
            build(model, shards, version)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_from(entries: &[(u32, f32)]) -> (Vec<u32>, Vec<f32>) {
        let indices: Vec<u32> = entries.iter().map(|e| e.0).collect();
        let values: Vec<f32> = entries.iter().map(|e| e.1).collect();
        (indices, values)
    }

    fn spanning_model_and_row() -> (LinearModel, Vec<u32>, Vec<f32>) {
        let d = 3 * SCORE_BLOCK as usize + 17;
        let mut m = LinearModel::zeros(d, Loss::Logistic);
        let mut rng = crate::util::Rng::new(11);
        for w in m.weights.iter_mut() {
            if rng.bool(0.01) {
                *w = rng.normal();
            }
        }
        m.bias = 0.37;
        let idx = rng.sample_distinct(d, 200);
        let (indices, values): (Vec<u32>, Vec<f32>) = idx
            .into_iter()
            .map(|j| (j as u32, (rng.normal() * 1.5) as f32))
            .unzip();
        (m, indices, values)
    }

    #[test]
    fn blocked_score_matches_sequential_within_one_block() {
        let mut m = LinearModel::zeros(10, Loss::Logistic);
        m.weights[3] = 2.0;
        m.weights[7] = -0.5;
        m.bias = 0.25;
        let (indices, values) = row_from(&[(3, 1.0), (7, 2.0)]);
        let row = RowView { indices: &indices, values: &values };
        // dim 10 fits in one block: blocked == fully sequential, bitwise.
        assert_eq!(Predictor::score(&m, row).to_bits(), m.score(row).to_bits());
    }

    #[test]
    fn partials_fold_reproduces_blocked_score() {
        let (m, indices, values) = spanning_model_and_row();
        let row = RowView { indices: &indices, values: &values };
        let mut partials = Vec::new();
        block_partials(row, &m.weights, 0, &mut partials);
        assert!(partials.windows(2).all(|w| w[0].0 < w[1].0), "ascending blocks");
        let folded = fold_score(m.bias, &partials);
        assert_eq!(folded.to_bits(), blocked_score(m.bias, row, &m.weights).to_bits());
    }

    #[test]
    fn blocked_score_close_to_sequential_across_blocks() {
        let (m, indices, values) = spanning_model_and_row();
        let row = RowView { indices: &indices, values: &values };
        let blocked = Predictor::score(&m, row);
        let sequential = m.score(row);
        assert!(
            (blocked - sequential).abs() <= 1e-9 * (1.0 + sequential.abs()),
            "blocked={blocked} sequential={sequential}"
        );
    }

    #[test]
    fn empty_row_scores_bias() {
        let m = LinearModel::zeros(8, Loss::Logistic);
        let row = RowView { indices: &[], values: &[] };
        assert_eq!(Predictor::score(&m, row), m.bias);
    }

    #[test]
    fn versioned_reports_version_and_delegates() {
        let mut m = LinearModel::zeros(4, Loss::Logistic);
        m.weights[1] = 1.0;
        let (indices, values) = row_from(&[(1, 2.0)]);
        let row = RowView { indices: &indices, values: &values };
        let expect = Predictor::score(&m, row);
        let v = Versioned::new(m, 7);
        assert_eq!(v.version(), 7);
        assert_eq!(v.score(row).to_bits(), expect.to_bits());
        assert_eq!(v.dim(), 4);
    }

    #[test]
    fn build_picks_implementation_by_shards() {
        let m = LinearModel::zeros(16, Loss::Logistic);
        let p1 = build(m.clone(), 1, 3);
        let p2 = build(m, 2, 4);
        assert_eq!(p1.version(), 3);
        assert_eq!(p2.version(), 4);
        let row = RowView { indices: &[], values: &[] };
        assert_eq!(p1.score(row), 0.0);
        assert_eq!(p2.score(row), 0.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn build_with_artifact_falls_back_without_runtime() {
        // The stub runtime can't construct the batcher, so this must
        // degrade to the native predictor at the requested version.
        let m = LinearModel::zeros(8, Loss::Logistic);
        let p = build_with_artifact(m, 2, 5);
        assert_eq!(p.version(), 5);
        assert_eq!(p.dim(), 8);
    }

    #[test]
    fn f32_fast_path_tracks_the_canonical_score() {
        let (m, indices, values) = spanning_model_and_row();
        let row = RowView { indices: &indices, values: &values };
        let canonical = Predictor::score(&m, row);
        let fast = F32Model::from_model(&m, 9);
        assert_eq!(fast.version(), 9);
        assert_eq!(fast.dim(), m.dim());
        let z = fast.score(row);
        // f32 rounding, not bitwise: the 200-nnz dot should agree to
        // ~1e-5 relative — far outside that means a kernel bug, inside
        // f64 bitwise would mean we are not actually on the f32 path.
        assert!(
            (z - canonical).abs() <= 1e-4 * (1.0 + canonical.abs()),
            "f32 score {z} vs canonical {canonical}"
        );
    }

    #[test]
    fn f32_kernel_handles_remainders_and_empty_rows() {
        let mut m = LinearModel::zeros(12, Loss::Logistic);
        for (j, w) in m.weights.iter_mut().enumerate() {
            *w = 0.25 * (j as f64 + 1.0); // exact in f32
        }
        m.bias = 0.5;
        // nnz from 0 through 6 covers empty, sub-chunk, exactly one
        // chunk, and chunk + remainder shapes.
        for nnz in 0..=6usize {
            let indices: Vec<u32> = (0..nnz as u32).map(|i| 2 * i).collect();
            let values: Vec<f32> = (0..nnz).map(|i| 0.5 * (i as f32 + 1.0)).collect();
            let row = RowView { indices: &indices, values: &values };
            let want: f64 = m.bias
                + indices
                    .iter()
                    .zip(values.iter())
                    .map(|(&j, &v)| f64::from(v) * m.weights[j as usize])
                    .sum::<f64>();
            let fast = F32Model::from_model(&m, 0);
            // All inputs exact in f32 and tiny sums: exact agreement.
            assert_eq!(fast.score(row), want, "nnz = {nnz}");
        }
    }

    #[test]
    fn build_f32_serves_the_fast_path_at_any_shard_request() {
        let mut m = LinearModel::zeros(8, Loss::Logistic);
        m.weights[3] = 1.5;
        m.bias = 0.25;
        let indices = [3u32];
        let values = [2.0f32];
        let row = RowView { indices: &indices, values: &values };
        for shards in [1usize, 4] {
            let p = build_f32(m.clone(), shards, 6);
            assert_eq!(p.version(), 6);
            assert_eq!(p.score(row), 0.25 + 3.0, "shards = {shards}");
        }
    }

    #[test]
    fn score_matrix_covers_all_rows() {
        let mut x = CsrMatrix::empty(8);
        x.push_row(vec![(1, 1.0)]);
        x.push_row(vec![]);
        x.push_row(vec![(7, 2.0)]);
        let mut m = LinearModel::zeros(8, Loss::Squared);
        m.weights[1] = 0.5;
        m.weights[7] = -1.0;
        let scores = Predictor::score_matrix(&m, &x);
        assert_eq!(scores, vec![0.5, 0.0, -2.0]);
    }
}
