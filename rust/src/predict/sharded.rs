//! Feature-sharded scoring: the weight vector partitioned by feature
//! range across N persistent worker threads.
//!
//! The serving dual of the example-sharded training engine
//! ([`crate::train::parallel`]): where training splits *examples* across
//! workers, serving a model too large for one node's cache (or node)
//! splits the *weight vector*. Each shard owns a contiguous range of
//! [`SCORE_BLOCK`]-aligned features — stored compactly as the range's
//! sorted nonzero `(index, weight)` pairs, so an ℓ1-sparse model costs
//! each worker O(range nnz) memory, not O(range) — a request broadcasts
//! the (owned) rows to every shard, each computes the block partial dot
//! products of its range with the sparse merge-join kernel
//! ([`sparse_block_partials`]), and the results are tree-reduced.
//!
//! ## Why the scores are bitwise-exact
//!
//! A shard's unit of work is an *ordered list* of `(block, partial)`
//! pairs, not a single float. Merging two adjacent shards concatenates
//! their lists (shard ranges ascend, so block order is preserved) —
//! concatenation is associative, so the tree-reduce shape is irrelevant —
//! and only the final [`fold_score`] performs the cross-block floating
//! point additions, in exactly the canonical order. Hence
//! `ShardedModel::score` equals the trait score of the unsharded
//! [`LinearModel`] bit for bit, for **any** shard count. Dropping the
//! zero weights does not disturb this: the merge-join emits the same
//! block list and skips only exact-`±0.0` products, which cannot change
//! any partial bitwise (see [`super::sparse`]).

use crate::sync::{lock_ok, mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::data::RowView;
use crate::loss::Loss;
use crate::model::LinearModel;

use super::{fold_score, sparse_block_partials, Predictor, SCORE_BLOCK};

/// Ordered `(block id, partial sum)` pairs for one row.
pub(crate) type RowPartials = Vec<(u32, f64)>;

/// Feature range `[lo, hi)` owned by shard `s` of `n_shards`: block-
/// aligned so within-block accumulation never crosses a shard. One
/// formula for both shard threads and remote shard servers
/// ([`crate::net::ShardServer`]) — bitwise equality between them rests
/// on partitioning identically.
pub(crate) fn shard_bounds(dim: usize, n_shards: usize, s: usize) -> (usize, usize) {
    let block = SCORE_BLOCK as usize;
    let n_blocks = dim.div_ceil(block);
    let lo = (s * n_blocks / n_shards * block).min(dim);
    let hi = ((s + 1) * n_blocks / n_shards * block).min(dim);
    (lo, hi)
}

/// Tree-reduce per-shard row results (indexed by shard) into one
/// per-row block-partial list. Merging two shards concatenates each
/// row's ordered list — associative, so the tree shape cannot change
/// the result. Shared by [`ShardedModel`] and the remote
/// [`crate::net::RemoteShardModel`] so both reduce identically.
pub(crate) fn reduce_partials(mut layer: Vec<Vec<RowPartials>>) -> Vec<RowPartials> {
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                for (l, r) in left.iter_mut().zip(right) {
                    l.extend(r);
                }
            }
            next.push(left);
        }
        layer = next;
    }
    layer.pop().unwrap_or_default()
}

/// A batch of owned rows, shared with every shard worker.
///
/// Deliberately *not* a [`crate::data::CsrMatrix`]: `push_row` re-sorts
/// and merges every row, which the already-valid `RowView`s on this hot
/// path don't need — this is a flat copy and nothing more.
struct SharedRows {
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl SharedRows {
    fn from_views(rows: &[RowView<'_>], dim: usize) -> SharedRows {
        let nnz = rows.iter().map(|r| r.nnz()).sum();
        let mut s = SharedRows {
            indptr: Vec::with_capacity(rows.len() + 1),
            indices: Vec::with_capacity(nnz),
            values: Vec::with_capacity(nnz),
        };
        s.indptr.push(0);
        for r in rows {
            // The shard split binary-searches each row, so the RowView
            // invariant (strictly increasing indices) is load-bearing.
            debug_assert!(
                r.indices.windows(2).all(|w| w[0] < w[1]),
                "RowView indices must be strictly increasing"
            );
            // Release builds silently ignore out-of-range features (the
            // range split excludes them), unlike the native impl's index
            // panic — the assert keeps the divergence loud where it can.
            debug_assert!(
                r.indices.iter().all(|&j| (j as usize) < dim),
                "RowView index out of range for dim {dim}"
            );
            s.indices.extend_from_slice(r.indices);
            s.values.extend_from_slice(r.values);
            s.indptr.push(s.indices.len());
        }
        s
    }

    fn len(&self) -> usize {
        self.indptr.len() - 1
    }

    fn row(&self, r: usize) -> RowView<'_> {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        RowView { indices: &self.indices[lo..hi], values: &self.values[lo..hi] }
    }
}

/// One shard's answer for a batch.
struct ShardResult {
    shard: usize,
    rows: Vec<RowPartials>,
}

enum Job {
    Score { rows: Arc<SharedRows>, reply: mpsc::Sender<ShardResult> },
    Stop,
}

struct ShardWorker {
    /// The sender is wrapped in a `Mutex` so `ShardedModel` is `Sync`
    /// without relying on `mpsc::Sender: Sync` (only true on newer
    /// toolchains); a send is a few ns, so contention is immaterial.
    tx: Mutex<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<()>>,
}

/// A [`Predictor`] whose weight vector lives in N shard worker threads,
/// partitioned by contiguous block-aligned feature ranges.
pub struct ShardedModel {
    workers: Vec<ShardWorker>,
    dim: usize,
    bias: f64,
    loss: Loss,
    version: u64,
}

impl ShardedModel {
    /// Spawn `n_shards` worker threads, each owning a contiguous
    /// block-aligned slice of `model`'s weights (clamped to at least 1).
    /// When shards outnumber blocks, the `s * n_blocks / n_shards`
    /// partition leaves the *leading* shards empty — e.g. one block
    /// across 7 shards puts everything on shard 6.
    pub fn spawn(model: &LinearModel, n_shards: usize, version: u64) -> ShardedModel {
        let n_shards = n_shards.max(1);
        let dim = model.weights.len();
        let mut workers = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let (lo, hi) = shard_bounds(dim, n_shards, s);
            // Compact the range: the worker holds only its nonzeros,
            // with *absolute* feature indices (the merge-join kernel
            // needs no base offset).
            let mut indices = Vec::new();
            let mut weights = Vec::new();
            for (k, &w) in model.weights[lo..hi].iter().enumerate() {
                if w != 0.0 {
                    indices.push((lo + k) as u32);
                    weights.push(w);
                }
            }
            let (tx, rx) = mpsc::channel::<Job>();
            let handle = std::thread::spawn(move || {
                shard_loop(s, lo as u32, hi as u32, indices, weights, rx)
            });
            workers.push(ShardWorker { tx: Mutex::new(tx), handle: Some(handle) });
        }
        ShardedModel { workers, dim, bias: model.bias, loss: model.loss, version }
    }

    /// Number of shard workers.
    pub fn n_shards(&self) -> usize {
        self.workers.len()
    }

    /// Broadcast a batch to every shard and collect per-shard results,
    /// indexed by shard.
    fn broadcast(&self, rows: Arc<SharedRows>) -> Vec<Vec<RowPartials>> {
        let (reply, results) = mpsc::channel();
        for w in &self.workers {
            let job = Job::Score { rows: rows.clone(), reply: reply.clone() };
            // `lock_ok`: a Mutex poisoned by some earlier panic still
            // guards a perfectly valid Sender, and Drop must be able to
            // lock it again either way.
            let sent = lock_ok(w.tx.lock()).send(job);
            sent.expect("shard worker exited");
        }
        drop(reply);
        let mut per_shard: Vec<Vec<RowPartials>> =
            (0..self.workers.len()).map(|_| Vec::new()).collect();
        for _ in 0..self.workers.len() {
            // A shard dying mid-batch drops its reply sender, so this
            // fails fast instead of hanging the caller.
            let res = results.recv().expect("shard worker died mid-batch");
            per_shard[res.shard] = res.rows;
        }
        per_shard
    }
}

fn shard_loop(
    shard: usize,
    lo: u32,
    hi: u32,
    indices: Vec<u32>,
    weights: Vec<f64>,
    rx: mpsc::Receiver<Job>,
) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::Score { rows, reply } => {
                let mut out = Vec::with_capacity(rows.len());
                for r in 0..rows.len() {
                    let row = rows.row(r);
                    // Indices are sorted, so the shard's slice is found by
                    // two binary searches.
                    let a = row.indices.partition_point(|&j| j < lo);
                    let b = row.indices.partition_point(|&j| j < hi);
                    let mut partials = RowPartials::new();
                    let idx = &row.indices[a..b];
                    let val = &row.values[a..b];
                    let slice = RowView { indices: idx, values: val };
                    sparse_block_partials(slice, &indices, &weights, &mut partials);
                    out.push(partials);
                }
                let _ = reply.send(ShardResult { shard, rows: out });
            }
            Job::Stop => break,
        }
    }
}

impl Predictor for ShardedModel {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss(&self) -> Loss {
        self.loss
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        self.score_batch(&[row])[0]
    }

    fn score_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        if rows.is_empty() {
            return Vec::new();
        }
        let shared = Arc::new(SharedRows::from_views(rows, self.dim));
        let merged = reduce_partials(self.broadcast(shared));
        merged.into_iter().map(|ps| fold_score(self.bias, &ps)).collect()
    }
}

impl Drop for ShardedModel {
    fn drop(&mut self) {
        for w in &self.workers {
            // `lock_ok`, not `if let Ok(..)`: skipping the Stop message
            // on a poisoned Mutex would leave that shard parked on
            // `recv` while its Sender is still alive in `self.workers`,
            // and the join below would hang Drop forever. (Panicking
            // here is not an option either — during an unwind it would
            // abort the process.)
            let _ = lock_ok(w.tx.lock()).send(Job::Stop);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_model(d: usize, seed: u64) -> LinearModel {
        let mut m = LinearModel::zeros(d, Loss::Logistic);
        let mut rng = Rng::new(seed);
        for w in m.weights.iter_mut() {
            if rng.bool(0.05) {
                *w = rng.normal();
            }
        }
        m.bias = rng.normal();
        m
    }

    fn random_row(d: usize, nnz: usize, rng: &mut Rng) -> (Vec<u32>, Vec<f32>) {
        let idx = rng.sample_distinct(d, nnz);
        idx.into_iter().map(|j| (j as u32, rng.normal() as f32)).unzip()
    }

    // The multi-block bitwise-parity property across shard counts
    // {1, 2, 7} lives in tests/serve_protocol.rs (the ISSUE coverage
    // item); the unit tests here keep the edge cases.

    #[test]
    fn more_shards_than_blocks_still_exact() {
        // dim < one block: only the last shard owns a non-empty range.
        let m = random_model(64, 9);
        let mut rng = Rng::new(3);
        let (indices, values) = random_row(64, 10, &mut rng);
        let row = RowView { indices: &indices, values: &values };
        let sm = ShardedModel::spawn(&m, 7, 0);
        assert_eq!(sm.score(row).to_bits(), Predictor::score(&m, row).to_bits());
    }

    #[test]
    fn empty_batch_and_empty_rows() {
        let m = random_model(256, 1);
        let sm = ShardedModel::spawn(&m, 3, 2);
        assert!(sm.score_batch(&[]).is_empty());
        let empty = RowView { indices: &[], values: &[] };
        assert_eq!(sm.score(empty), m.bias);
        assert_eq!(sm.version(), 2);
        assert_eq!(sm.dim(), 256);
    }

    #[test]
    fn predictions_apply_the_loss() {
        let m = random_model(128, 8);
        let mut rng = Rng::new(21);
        let (indices, values) = random_row(128, 12, &mut rng);
        let row = RowView { indices: &indices, values: &values };
        let sm = ShardedModel::spawn(&m, 2, 0);
        let p = sm.predict(row);
        assert_eq!(p, crate::loss::sigmoid(sm.score(row)));
    }

    #[test]
    fn drop_tolerates_a_poisoned_sender_mutex() {
        // Poison one shard's sender Mutex the only way a real panic
        // would: while holding the guard. Drop must still deliver Stop
        // to that shard — skipping it would park the shard on `recv`
        // forever and hang the join (the regression this test pins).
        let m = random_model(64, 5);
        let sm = ShardedModel::spawn(&m, 2, 1);
        let poisoned = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _guard = sm.workers[0].tx.lock().unwrap();
                    panic!("poison the sender mutex");
                })
                .join()
        });
        assert!(poisoned.is_err());
        assert!(sm.workers[0].tx.lock().is_err(), "mutex should be poisoned");
        drop(sm); // must neither panic nor hang
    }
}
