//! Batch scoring through the AOT `predict` artifact (Layer 2/1 via PJRT).
//!
//! The artifact computes `p[B] = σ(X·w + b)` over fixed-shape dense
//! mini-batches, so this predictor's hot path is [`Predictor::predict_batch`]:
//! rows are densified into the artifact's `batch × dim` shape (features
//! `>= dim` are dropped, mirroring [`crate::data::BatchIter`]) and scored
//! in chunks. Single-row scoring falls back to the native blocked kernel
//! over the same truncated weights, so both paths see identical feature
//! sets — but **not identical arithmetic**: the artifact computes in f32
//! (dot and sigmoid in-graph) while the native path is f64, so `predict`
//! and `predict_batch` can disagree by f32-rounding scale (~1e-6 of
//! probability, more for large-magnitude scores).
//!
//! Construction requires [`Runtime::load`] to succeed, which only happens
//! in builds with the `pjrt` cargo feature — the default offline stub
//! errors and this type is simply never instantiated (callers fall back
//! to the native or sharded predictor).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::data::RowView;
use crate::loss::Loss;
use crate::model::LinearModel;
use crate::runtime::Runtime;

use super::{blocked_score, Predictor};

/// A [`Predictor`] that scores dense mini-batches through the compiled
/// `predict` artifact.
pub struct ArtifactBatcher {
    rt: Runtime,
    batch: usize,
    /// The artifact's dense feature dimension; features at or beyond it
    /// are *dropped* when scoring, never rejected.
    art_dim: usize,
    /// The model's nominal dimensionality (what [`Predictor::dim`]
    /// reports, so request validation is independent of artifact shape).
    model_dim: usize,
    /// f64 weights truncated/padded to the artifact dim (native path).
    weights: Vec<f64>,
    /// f32 copy handed to the artifact.
    weights_f32: Vec<f32>,
    bias: f64,
    version: u64,
}

impl ArtifactBatcher {
    /// Load the artifacts in `dir` and bind `model`'s weights to them.
    ///
    /// Fails when the runtime is unavailable (offline stub build), when
    /// the artifacts are missing, or when the model's loss is not
    /// logistic (the artifact bakes in the sigmoid).
    pub fn load(dir: &Path, model: &LinearModel, version: u64) -> Result<ArtifactBatcher> {
        ensure!(
            model.loss == Loss::Logistic,
            "predict artifact is logistic-only (model loss: {})",
            model.loss.name()
        );
        let rt = Runtime::load(dir).context("load PJRT artifacts")?;
        let meta = rt.meta();
        ensure!(meta.batch > 0 && meta.dim > 0, "degenerate artifact shapes: {meta:?}");
        let mut weights = vec![0.0f64; meta.dim];
        for (j, &w) in model.weights.iter().take(meta.dim).enumerate() {
            weights[j] = w;
        }
        let weights_f32 = weights.iter().map(|&w| w as f32).collect();
        Ok(ArtifactBatcher {
            rt,
            batch: meta.batch,
            art_dim: meta.dim,
            model_dim: model.weights.len(),
            weights,
            weights_f32,
            bias: model.bias,
            version,
        })
    }

    /// The artifact's fixed mini-batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

impl Predictor for ArtifactBatcher {
    fn dim(&self) -> usize {
        self.model_dim
    }

    fn loss(&self) -> Loss {
        Loss::Logistic
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn score(&self, row: RowView<'_>) -> f64 {
        // Native fallback over the truncated weights; features >= the
        // artifact dim contribute nothing, exactly as in the batch path.
        let cut = row.indices.partition_point(|&j| (j as usize) < self.art_dim);
        let slice = RowView { indices: &row.indices[..cut], values: &row.values[..cut] };
        blocked_score(self.bias, slice, &self.weights)
    }

    fn predict_batch(&self, rows: &[RowView<'_>]) -> Vec<f64> {
        let mut out = Vec::with_capacity(rows.len());
        let mut x = vec![0.0f32; self.batch * self.art_dim];
        for chunk in rows.chunks(self.batch) {
            x.fill(0.0);
            for (b, row) in chunk.iter().enumerate() {
                let dst = &mut x[b * self.art_dim..(b + 1) * self.art_dim];
                for (j, v) in row.iter() {
                    if (j as usize) < self.art_dim {
                        dst[j as usize] = v;
                    }
                }
            }
            match self.rt.predict(&x, &self.weights_f32, self.bias as f32) {
                Ok(probs) => {
                    out.extend(probs.iter().take(chunk.len()).map(|&p| f64::from(p)));
                }
                // Keep serving if the runtime hiccups: score natively.
                Err(_) => out.extend(chunk.iter().map(|&r| self.predict(r))),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_build_cannot_construct() {
        let model = LinearModel::zeros(8, Loss::Logistic);
        let err = ArtifactBatcher::load(Path::new("artifacts"), &model, 1).unwrap_err();
        assert!(err.to_string().contains("PJRT") || err.to_string().contains("artifacts"), "{err}");
    }

    #[test]
    fn rejects_non_logistic_models() {
        let model = LinearModel::zeros(8, Loss::Hinge);
        let err = ArtifactBatcher::load(Path::new("artifacts"), &model, 1).unwrap_err();
        assert!(err.to_string().contains("logistic"), "{err}");
    }
}
