//! Sparse-merge equivalence suite: the O(touched) data-parallel sync
//! (`--merge sparse`) must be a pure optimization of the flat merge —
//! same model to float tolerance — across every penalty family, both
//! update algorithms, the learning-rate schedules and sync cadences,
//! with lazy and dense workers, and under coordinated budget flushes.
//!
//! The shared-table invariant itself (untouched slots stay lazy and
//! identical across workers after a sparse sync) is pinned at unit scale
//! in `train::pool`'s tests; here the whole engine is exercised.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::prelude::*;
use lazyreg::synth::{generate, BowSpec};
use lazyreg::testing::property;
use lazyreg::train::train_parallel_dense_xy;

#[test]
fn sparse_merge_equals_flat_across_families_algos_and_schedules() {
    // n = 500 is divisible by every worker count drawn below, so the
    // sparse sync never falls back — each case genuinely runs the
    // O(touched) path.
    let data = generate(&BowSpec::tiny(), 91);
    property("sparse merge == flat merge", 12, |g| {
        let algo = *g.choose(&[Algo::Sgd, Algo::Fobos]);
        let reg = *g.choose(&[
            Regularizer::none(),
            Regularizer::l1(0.005),
            Regularizer::l22(0.1),
            Regularizer::elastic_net(0.003, 0.1),
            Regularizer::truncated_gradient(0.005, 4, 0.8),
            Regularizer::linf(0.6),
        ]);
        let schedule = *g.choose(&[
            Schedule::Constant { eta0: 0.3 },
            Schedule::InvT { eta0: 0.8 },
            Schedule::InvSqrtT { eta0: 0.5 },
        ]);
        let workers = *g.choose(&[2usize, 4, 5]);
        let sync_interval = Some(*g.choose(&[10usize, 25, 64]));
        let flat = TrainOptions {
            algo,
            reg,
            schedule,
            epochs: 2,
            workers,
            sync_interval,
            seed: 0xBEEF ^ g.case as u64,
            ..Default::default()
        };
        let sparse = TrainOptions { merge: MergeMode::Sparse, ..flat };
        let a = train_parallel(&data, &flat).unwrap();
        let b = train_parallel(&data, &sparse).unwrap();
        let diff = a.model.max_weight_diff(&b.model);
        assert!(
            diff < 1e-10,
            "case {}: {algo:?}/{}/{schedule:?} workers={workers} \
             sync={sync_interval:?}: sparse vs flat diff {diff}",
            g.case,
            reg.name(),
        );
        assert!((a.model.bias - b.model.bias).abs() < 1e-10);
        // Identical example schedule on both sides: the loss curves
        // agree to the same tolerance class.
        for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
            assert!((ea.mean_loss - eb.mean_loss).abs() < 1e-9);
        }
    });
}

#[test]
fn sparse_engine_lazy_matches_dense_workers() {
    // The paper's lazy == dense per-update equivalence survives the
    // sparse sync: dense workers take the same gather/scatter schedule
    // (their untouched weights are provably identical across workers),
    // so both engines walk the same trajectory up to rounding.
    let data = generate(&BowSpec::tiny(), 92);
    let o = TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-4, 1e-3),
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 2,
        workers: 4,
        sync_interval: Some(20),
        merge: MergeMode::Sparse,
        ..Default::default()
    };
    let lazy = train_parallel(&data, &o).unwrap();
    let dense = train_parallel_dense_xy(data.x(), data.labels(), &o).unwrap();
    let diff = lazy.model.max_weight_diff(&dense.model);
    assert!(diff < 1e-8, "sparse lazy vs dense diff {diff}");
    assert!(lazy.final_loss() < lazy.epochs[0].mean_loss, "sparse run did not learn");
}

#[test]
fn tiny_space_budget_triggers_the_coordinated_flush() {
    // Budget 18 with interval 16: a round adds at most 16 table slots,
    // so no worker ever rebases mid-round (the table peaks at 17 < 18),
    // but at every boundary `len + next_steps >= budget` — the
    // coordinator must flush **all** workers there, together.
    let data = generate(&BowSpec::tiny(), 93);
    let workers = 4usize;
    let flat = TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-4, 1e-3),
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 2,
        workers,
        sync_interval: Some(16),
        space_budget: Some(18),
        ..Default::default()
    };
    let sparse = TrainOptions { merge: MergeMode::Sparse, ..flat };
    let a = train_parallel(&data, &flat).unwrap();
    let b = train_parallel(&data, &sparse).unwrap();
    // Flat rebases through every round's `load_weights` broadcast (not
    // counted as amortized flushes), so its counter stays 0; under the
    // same pressure the sparse engine must flush, and in lockstep.
    assert_eq!(a.rebases, 0);
    assert!(b.rebases > 0, "rebase pressure never triggered the coordinated flush");
    assert_eq!(
        b.rebases % workers as u64,
        0,
        "workers flushed out of lockstep: {} rebases over {workers} workers",
        b.rebases
    );
    // And the flush is invisible to the trained model.
    let diff = a.model.max_weight_diff(&b.model);
    assert!(diff < 1e-10, "coordinated flush changed the model: diff {diff}");
}

#[test]
fn sparse_merge_shrinks_the_synced_weight_volume() {
    // The point of the optimization, asserted structurally rather than
    // by wall clock: on a sparse corpus the per-round merge set is a
    // small fraction of d, while every dense merge moves all of d.
    let data = generate(&BowSpec::tiny(), 94);
    let o = TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-4, 1e-3),
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 2,
        workers: 4,
        sync_interval: Some(10),
        merge: MergeMode::Sparse,
        ..Default::default()
    };
    let report = train_parallel(&data, &o).unwrap();
    for e in &report.epochs {
        // 4 workers x 10 examples x ~20 distinct tokens bounds |U| by
        // 800 of d = 2000; Zipf reuse pushes it far lower.
        assert!(
            e.touched_frac > 0.0 && e.touched_frac < 0.5,
            "epoch {}: touched_frac {} not sparse",
            e.epoch,
            e.touched_frac
        );
    }
}
