//! Deterministic fault injection against the cross-node stack, in one
//! process: every failure a [`ChaosProxy`] can manufacture — dropped
//! links, stalls longer than a deadline, header bit-flips, duplicated
//! bytes — must end in a structured error, a successful failover, or a
//! byte-faithful resume. Never a hang, never silent corruption.
//!
//! The suite proves the PR's four robustness promises end to end:
//!
//! * round checkpoints are semantically neutral — a `--checkpoint-every 1`
//!   run matches an uncheckpointed run within the flush-equivalence
//!   tolerance (1e-10, the same bound `rebase_preserves_semantics_across_flush`
//!   holds the DP tables to);
//! * a `--net-halt-after` drill aborts the fleet with a forced
//!   checkpoint, and `--resume` from it is **bitwise** identical to the
//!   uninterrupted run with the same checkpoint cadence (checkpoints
//!   sit on flush boundaries, where restore is exact);
//! * resume refuses a checkpoint whose recorded config disagrees with
//!   the relaunch, instead of silently training something else;
//! * link faults between a worker and the coordinator surface as
//!   structured aborts within the deadline budget — including a seeded
//!   sweep where *any* outcome other than "clean abort" or "bitwise
//!   correct result" fails the test;
//! * replica failover on the serving path rides through a chaotic
//!   replica bitwise-identically to the in-process predictor.
//!
//! Deadlines are shrunk (see [`short_deadlines`]) so every failure
//! resolves in milliseconds-to-seconds; the elapsed-time assertions are
//! the no-hang guarantee.

// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use lazyreg::data::CsrMatrix;
use lazyreg::loss::Loss;
use lazyreg::model::LinearModel;
use lazyreg::net::frame::FrameError;
use lazyreg::net::{
    run_worker_with, ChaosProxy, Checkpoint, CheckpointConfig, ClusterCoordinator, Deadlines,
    Fault, FaultPlan, NetStats, RemoteShardModel, ShardServer,
};
use lazyreg::optim::Regularizer;
use lazyreg::predict::{self, Predictor};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::train::{MergeMode, TrainOptions, TrainReport};
use lazyreg::util::Rng;

/// Tight liveness bounds so injected faults resolve fast. The stalls
/// this suite injects are either shorter than every read bound
/// (survivable) or longer than `silence` (must trip [`FrameError::Timeout`]).
fn short_deadlines() -> Deadlines {
    Deadlines {
        reply: Duration::from_millis(500),
        silence: Duration::from_millis(1_000),
        round: Duration::from_millis(2_000),
        write: Duration::from_millis(500),
        heartbeat: Duration::from_millis(100),
        failover: Duration::from_millis(400),
    }
}

/// 500 examples / 2 workers / interval 50 = 5 rounds per epoch, 10
/// rounds over the 2-epoch run — enough boundaries to checkpoint at,
/// halt inside, and resume across an epoch edge.
fn train_opts() -> TrainOptions {
    TrainOptions {
        epochs: 2,
        workers: 2,
        merge: MergeMode::Sparse,
        sync_interval: Some(50),
        reg: Regularizer::elastic_net(1e-4, 1e-4),
        seed: 13,
        ..Default::default()
    }
}

fn tmp_ckpt(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("lazyreg-net-chaos-{}-{name}.lzck", std::process::id()));
    p
}

/// Run one coordinated cluster under [`short_deadlines`]. `route` maps
/// the coordinator's bound address to the address each worker dials —
/// identity for a healthy fleet, a [`ChaosProxy`] for a faulty link.
/// Worker threads never panic on protocol failure; their `Result`s come
/// back alongside the coordinator's so tests can assert *which* side
/// saw a structured error.
fn run_cluster<F>(
    x: &CsrMatrix,
    labels: &[f32],
    opts: &TrainOptions,
    ckpt: Option<&CheckpointConfig>,
    route: F,
) -> (anyhow::Result<(TrainReport, NetStats)>, Vec<anyhow::Result<()>>)
where
    F: FnOnce(SocketAddr) -> Vec<String>,
{
    let dl = short_deadlines();
    let coord = ClusterCoordinator::bind_with("127.0.0.1:0", opts.workers, dl).expect("bind");
    let addrs = route(coord.addr());
    assert_eq!(addrs.len(), opts.workers, "route must address every worker");
    std::thread::scope(|s| {
        let handles: Vec<_> = addrs
            .iter()
            .map(|addr| s.spawn(move || run_worker_with(addr, x, labels, opts, &dl)))
            .collect();
        let coord_res = coord.run_with(x, labels, opts, ckpt);
        let workers =
            handles.into_iter().map(|h| h.join().expect("worker thread panicked")).collect();
        (coord_res, workers)
    })
}

fn direct(addr: SocketAddr) -> Vec<String> {
    vec![addr.to_string(), addr.to_string()]
}

fn assert_bitwise_eq(a: &LinearModel, b: &LinearModel, what: &str) {
    assert_eq!(a.bias.to_bits(), b.bias.to_bits(), "{what}: bias {} vs {}", a.bias, b.bias);
    assert_eq!(a.weights.len(), b.weights.len(), "{what}: dim");
    for (j, (x, y)) in a.weights.iter().zip(b.weights.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: weight {j}: {x} vs {y}");
    }
}

fn frame_error_in_chain(err: &anyhow::Error, want: impl Fn(&FrameError) -> bool) -> bool {
    err.chain().any(|c| c.downcast_ref::<FrameError>().is_some_and(&want))
}

// ----------------------------------------------- checkpoints and resume

#[test]
fn round_checkpoints_do_not_perturb_training() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();

    let (plain, workers) = run_cluster(data.x(), data.labels(), &opts, None, direct);
    let (plain, _) = plain.expect("plain cluster");
    for w in workers {
        w.expect("plain worker");
    }

    let path = tmp_ckpt("cadence");
    let cfg = CheckpointConfig { path: path.clone(), every: 1, resume: false, halt_after: None };
    let (ck, workers) = run_cluster(data.x(), data.labels(), &opts, Some(&cfg), direct);
    let (ck, stats) = ck.expect("checkpointed cluster");
    for w in workers {
        w.expect("checkpointed worker");
    }

    // Checkpoint rounds force a flush the plain run may not take, so
    // the bound is flush-equivalence (1e-10), not bitwise.
    let diff = ck.model.max_weight_diff(&plain.model);
    assert!(diff < 1e-10, "checkpoint cadence perturbed training: weight diff {diff}");
    assert_eq!(ck.penalty, plain.penalty);
    assert_eq!(ck.examples, plain.examples);
    assert_eq!(stats.rounds, 10, "2 epochs x 5 rounds");

    // The last snapshot on disk is from the final checkpointable round
    // (the terminal round has no successor steps, so cadence skips it)
    // and round-trips through the LZCK codec.
    let snap = Checkpoint::load(&path).expect("loading the last checkpoint");
    assert_eq!(snap.round, 9, "last cadence checkpoint restarts at the final round");
    assert_eq!(snap.workers, 2);
    assert_eq!(snap.seed, opts.seed);
    assert!(!snap.indices.is_empty(), "a trained model has nonzeros to snapshot");
    std::fs::remove_file(&path).ok();
}

#[test]
fn halt_and_resume_is_bitwise_identical_to_the_uninterrupted_run() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();

    // The reference: uninterrupted, same checkpoint cadence (cadence
    // changes the flush schedule, so only the same-cadence run is the
    // bitwise target).
    let ref_path = tmp_ckpt("resume-ref");
    let ref_cfg =
        CheckpointConfig { path: ref_path.clone(), every: 1, resume: false, halt_after: None };
    let (unint, workers) = run_cluster(data.x(), data.labels(), &opts, Some(&ref_cfg), direct);
    let (unint, _) = unint.expect("uninterrupted checkpointed cluster");
    for w in workers {
        w.expect("uninterrupted worker");
    }

    // The drill: same job, killed after round 3 with a forced snapshot.
    let path = tmp_ckpt("resume-drill");
    let halt_cfg =
        CheckpointConfig { path: path.clone(), every: 1, resume: false, halt_after: Some(3) };
    let (halted, workers) = run_cluster(data.x(), data.labels(), &opts, Some(&halt_cfg), direct);
    let err = halted.expect_err("halt_after must abort the coordinator");
    assert!(
        format!("{err:#}").contains("halting after round 3"),
        "halt reason must name the round: {err:#}"
    );
    for w in &workers {
        assert!(w.is_err(), "every worker must see the abort, not hang");
    }
    let snap = Checkpoint::load(&path).expect("the halt drill must leave a checkpoint");
    assert_eq!(snap.round, 4, "a round-3 halt restarts at round 4");

    // The relaunch: resume from the snapshot and finish the job.
    let res_cfg =
        CheckpointConfig { path: path.clone(), every: 1, resume: true, halt_after: None };
    let (resumed, workers) = run_cluster(data.x(), data.labels(), &opts, Some(&res_cfg), direct);
    let (resumed, stats) = resumed.expect("resumed cluster");
    for w in workers {
        w.expect("resumed worker");
    }
    assert_eq!(stats.rounds, 6, "resume replays rounds 4..10, not the whole job");
    assert_bitwise_eq(&resumed.model, &unint.model, "resumed vs uninterrupted");
    assert_eq!(resumed.penalty, unint.penalty);
    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_job() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();

    let path = tmp_ckpt("resume-drift");
    let halt_cfg =
        CheckpointConfig { path: path.clone(), every: 1, resume: false, halt_after: Some(1) };
    let (halted, _) = run_cluster(data.x(), data.labels(), &opts, Some(&halt_cfg), direct);
    halted.expect_err("halt_after must abort");

    // Relaunch with a drifted config: the coordinator must refuse the
    // snapshot loudly instead of resuming a different job from it.
    let mut drifted = opts.clone();
    drifted.seed = 14;
    let res_cfg =
        CheckpointConfig { path: path.clone(), every: 1, resume: true, halt_after: None };
    let (res, workers) = run_cluster(data.x(), data.labels(), &drifted, Some(&res_cfg), direct);
    let err = res.expect_err("config drift must refuse to resume");
    assert!(
        format!("{err:#}").contains("disagrees with this run"),
        "refusal must name the drift: {err:#}"
    );
    for w in workers {
        assert!(w.is_err(), "workers of a refused resume must fail, not hang");
    }
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------------- link-fault injection

/// Run the cluster with worker 1's link routed through a [`ChaosProxy`]
/// replaying `plan`; returns (coordinator result, worker results,
/// elapsed).
fn run_with_chaotic_link(
    x: &CsrMatrix,
    labels: &[f32],
    opts: &TrainOptions,
    plan: FaultPlan,
) -> (anyhow::Result<(TrainReport, NetStats)>, Vec<anyhow::Result<()>>, Duration) {
    let t0 = Instant::now();
    let mut proxy: Option<ChaosProxy> = None;
    let (coord_res, workers) = run_cluster(x, labels, opts, None, |addr| {
        let p = ChaosProxy::spawn(&addr.to_string(), plan).expect("chaos proxy");
        let via = p.addr().to_string();
        proxy = Some(p);
        vec![addr.to_string(), via]
    });
    let took = t0.elapsed();
    if let Some(p) = proxy {
        p.shutdown();
    }
    (coord_res, workers, took)
}

#[test]
fn dropped_worker_link_is_a_structured_abort_not_a_hang() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();
    // Sever worker 1's uplink 64 bytes in — mid-handshake or inside the
    // first sync push, depending on frame sizes; both must abort clean.
    let plan = FaultPlan { to_upstream: vec![Fault::Drop { after: 64 }], to_client: vec![] };
    let (coord_res, workers, took) = run_with_chaotic_link(data.x(), data.labels(), &opts, plan);
    assert!(took < Duration::from_secs(30), "dropped link must resolve fast, took {took:?}");
    let err = coord_res.expect_err("a dead worker link must abort the coordinator");
    assert!(
        frame_error_in_chain(&err, |f| matches!(f, FrameError::Truncated | FrameError::Timeout)),
        "abort must be rooted in a transport error: {err:#}"
    );
    assert!(workers.iter().any(|w| w.is_err()), "the severed worker must fail too");
}

#[test]
fn stalled_worker_link_trips_the_read_deadline() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();
    // Stall the uplink from byte 0 for longer than every read bound:
    // the coordinator must diagnose a stalled peer, not wait forever.
    let plan = FaultPlan {
        to_upstream: vec![Fault::Stall { after: 0, pause: Duration::from_secs(3) }],
        to_client: vec![],
    };
    let (coord_res, workers, took) = run_with_chaotic_link(data.x(), data.labels(), &opts, plan);
    assert!(took < Duration::from_secs(30), "stall must resolve via deadline, took {took:?}");
    let err = coord_res.expect_err("a stalled worker must abort the coordinator");
    assert!(
        frame_error_in_chain(&err, |f| matches!(f, FrameError::Timeout | FrameError::Truncated)),
        "stall must surface as a deadline (or the proxy teardown): {err:#}"
    );
    assert!(workers.iter().any(|w| w.is_err()));
}

#[test]
fn flipped_header_bit_is_a_structured_decode_error() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = train_opts();
    // Flip one bit inside the first frame header's magic on the uplink:
    // the coordinator must reject the bytes structurally, never panic
    // and never act on them.
    let plan =
        FaultPlan { to_upstream: vec![Fault::Flip { at: 2, bit: 0 }], to_client: vec![] };
    let (coord_res, workers, took) = run_with_chaotic_link(data.x(), data.labels(), &opts, plan);
    assert!(took < Duration::from_secs(30), "bit flip must resolve fast, took {took:?}");
    let err = coord_res.expect_err("corrupted magic must abort the handshake");
    assert!(
        frame_error_in_chain(&err, |f| matches!(f, FrameError::BadMagic(_))),
        "a flipped magic byte must decode as BadMagic: {err:#}"
    );
    assert!(workers.iter().any(|w| w.is_err()));
}

#[test]
fn seeded_fault_sweep_never_hangs_and_never_corrupts() {
    let data = generate(&BowSpec::tiny(), 97);
    let mut opts = train_opts();
    opts.epochs = 1; // 5 rounds per run keeps the sweep quick

    let (reference, workers) = run_cluster(data.x(), data.labels(), &opts, None, direct);
    let (reference, _) = reference.expect("reference cluster");
    for w in workers {
        w.expect("reference worker");
    }

    // Survivable stalls only (shorter than the 500 ms reply bound):
    // a seeded Stall must ride through; Drop/Flip/Duplicate must abort.
    // Either way the run ends inside the deadline budget, and an Ok run
    // must be *bitwise* the reference — a fault can delay training or
    // kill it, but never change what it computes.
    for seed in 0..6u64 {
        let plan = FaultPlan::seeded(seed, Duration::from_millis(200));
        let (coord_res, workers, took) =
            run_with_chaotic_link(data.x(), data.labels(), &opts, plan);
        assert!(took < Duration::from_secs(30), "seed {seed}: run took {took:?}");
        match coord_res {
            Ok((report, _)) => {
                assert_bitwise_eq(
                    &report.model,
                    &reference.model,
                    &format!("seed {seed}: survived run"),
                );
                for w in workers {
                    assert!(w.is_ok(), "seed {seed}: coordinator succeeded, workers must too");
                }
            }
            Err(err) => {
                // Structured abort — any anyhow chain is fine, but the
                // severed worker must have failed as well, not hung.
                assert!(
                    workers.iter().any(|w| w.is_err()),
                    "seed {seed}: abort without a failed worker: {err:#}"
                );
            }
        }
    }
}

// --------------------------------------------- serving-path failover

#[test]
fn replica_failover_rides_through_a_chaotic_replica_bitwise() {
    let dim = 512usize;
    let mut model = LinearModel::zeros(dim, Loss::Logistic);
    let mut rng = Rng::new(5);
    for w in model.weights.iter_mut() {
        if rng.bool(0.3) {
            *w = rng.normal();
        }
    }
    model.bias = 0.25;
    let spec = BowSpec { n_examples: 24, n_features: dim, avg_nnz: 12.0, ..Default::default() };
    let data = generate(&spec, 11);
    let local = predict::build(model.clone(), 1, 1);

    let dl = short_deadlines();
    // Replica A sits behind a proxy that severs its first connection
    // 200 bytes into the downlink — past the handshake, inside an early
    // scoring reply. Replica B is healthy and direct.
    let a = ShardServer::spawn_with(&model, 0, 1, "127.0.0.1:0", 1, dl).expect("replica a");
    let plan = FaultPlan { to_upstream: vec![], to_client: vec![Fault::Drop { after: 200 }] };
    let proxy = ChaosProxy::spawn(&a.addr().to_string(), plan).expect("chaos proxy");
    let b = ShardServer::spawn_with(&model, 0, 1, "127.0.0.1:0", 1, dl).expect("replica b");

    let group = vec![format!("{}|{}", proxy.addr(), b.addr())];
    let remote = RemoteShardModel::connect_with(&model, &group, 1, dl).expect("connect");

    // Every batch must come back, and bitwise equal to the in-process
    // predictor — the failover resend is stateless, so the client
    // cannot tell which replica scored it.
    let rows: Vec<_> = (0..data.n_examples()).map(|r| data.x().row(r)).collect();
    for batch in rows.chunks(8) {
        let want = local.score_batch(batch);
        let got = remote.try_score_batch(batch).expect("failover must absorb the drop");
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(got.iter()) {
            assert_eq!(w.to_bits(), g.to_bits(), "failover changed a score: {w} vs {g}");
        }
    }

    proxy.shutdown();
    a.shutdown();
    b.shutdown();
}
