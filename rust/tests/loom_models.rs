//! Exhaustive interleaving checks of the crate's coordination
//! primitives, run against the **real** types through the model-backed
//! face of the sync facade:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release -p lazyreg --test loom_models
//! ```
//!
//! Under `--cfg loom` every `crate::sync` Mutex/Condvar/atomic access is
//! a scheduling decision point and `model(|| ...)` re-runs each closure
//! under every interleaving within the preemption bound
//! (`LAZYREG_LOOM_PREEMPTIONS`, default 2 — the CHESS result: almost
//! all concurrency bugs surface within two preemptions). An assertion
//! failure in *any* schedule fails the test and prints the schedule.
//!
//! The invariants checked here are the ones `CONCURRENCY.md` documents:
//! barrier rendezvous + poison-wakes-parked-waiter, seq-slot publish
//! ordering + poison, queue close/drain + poison, and the hogwild
//! cell's no-double-catch-up pairing rule.

#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use lazyreg::sync::atomic::{AtomicUsize, Ordering};
use lazyreg::sync::model::{model, thread};
use lazyreg::sync::{Arc, BoundedQueue, HogwildCell, RoundBarrier, SeqSlot};

// ---------------------------------------------------------------- barrier

#[test]
fn barrier_rendezvous_releases_no_party_early() {
    model(|| {
        let barrier = Arc::new(RoundBarrier::new(2));
        let arrived = Arc::new(AtomicUsize::new(0));
        let (b2, a2) = (Arc::clone(&barrier), Arc::clone(&arrived));
        let t = thread::spawn(move || {
            a2.fetch_add(1, Ordering::SeqCst);
            b2.wait();
            // Rendezvous contract: nobody crosses until everybody arrived.
            assert_eq!(a2.load(Ordering::SeqCst), 2);
        });
        arrived.fetch_add(1, Ordering::SeqCst);
        barrier.wait();
        assert_eq!(arrived.load(Ordering::SeqCst), 2);
        t.join().unwrap();
    });
}

#[test]
fn barrier_reuse_across_two_rounds() {
    model(|| {
        let barrier = Arc::new(RoundBarrier::new(2));
        let b2 = Arc::clone(&barrier);
        let t = thread::spawn(move || {
            b2.wait();
            b2.wait();
        });
        barrier.wait();
        barrier.wait();
        t.join().unwrap();
    });
}

#[test]
fn barrier_poison_wakes_a_parked_waiter_in_every_schedule() {
    model(|| {
        let barrier = Arc::new(RoundBarrier::new(2)); // never completed
        let b2 = Arc::clone(&barrier);
        // The waiter parks (party 2 never arrives) or hits the poison
        // flag on entry, depending on the schedule; either way it must
        // panic, never hang.
        let t = thread::spawn(move || b2.wait());
        barrier.poison();
        assert!(t.join().is_err(), "poisoned waiter should panic, not hang");
    });
}

// --------------------------------------------------------------- seq slot

#[test]
fn seq_slot_waiter_gets_exactly_the_published_sequence() {
    model(|| {
        let slot: Arc<SeqSlot<usize>> = Arc::new(SeqSlot::new());
        let s2 = Arc::clone(&slot);
        let t = thread::spawn(move || {
            s2.publish(0, 41);
            s2.publish(1, 42);
        });
        // Consumers take sequences in order; whatever the interleaving,
        // waiting for seq 1 must return seq 1's value, never seq 0's.
        assert_eq!(slot.wait_for(1), 42);
        t.join().unwrap();
    });
}

#[test]
fn seq_slot_poison_wakes_a_parked_waiter_in_every_schedule() {
    model(|| {
        let slot: Arc<SeqSlot<usize>> = Arc::new(SeqSlot::new());
        let s2 = Arc::clone(&slot);
        let t = thread::spawn(move || s2.wait_for(3)); // never published
        slot.poison();
        assert!(t.join().is_err(), "poisoned waiter should panic, not hang");
    });
}

// ------------------------------------------------------------------ queue

#[test]
fn queue_close_semantics_under_every_schedule() {
    model(|| {
        // Capacity 1 forces the producer through the full/backpressure
        // path in some schedules.
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || {
            let a = q2.push(1);
            let b = q2.push(2);
            q2.close();
            (a, b)
        });
        let mut got = Vec::new();
        while let Some(v) = q.pop() {
            got.push(v);
        }
        let (a, b) = t.join().unwrap();
        assert!(a && b, "producer finished before close: both pushes accepted");
        assert_eq!(got, vec![1, 2], "FIFO, nothing lost, None only after drain");
    });
}

#[test]
fn queue_poison_wakes_a_parked_consumer_in_every_schedule() {
    model(|| {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let q2 = Arc::clone(&q);
        let t = thread::spawn(move || q2.pop()); // parks: nothing to pop
        q.poison();
        assert!(t.join().is_err(), "poisoned consumer should panic, not hang");
    });
}

// ----------------------------------------------------------- hogwild cell

#[test]
fn hogwild_cell_never_pairs_fresh_weight_with_stale_psi() {
    // The ψ-stamp invariant the lock-free engine's catch-up correctness
    // rests on: a reader that sees the published weight must see a ψ at
    // least as new as its stamp — otherwise it would re-apply (double)
    // the catch-up the writer already folded in.
    model(|| {
        let cell = Arc::new(HogwildCell::new(1.0));
        let c2 = Arc::clone(&cell);
        let t = thread::spawn(move || c2.publish(1, 2.0));
        let (w, psi) = cell.read();
        t.join().unwrap();
        assert!(
            !(w == 2.0 && psi < 1),
            "fresh weight paired with stale ψ: double catch-up (w={w}, psi={psi})"
        );
    });
}

#[test]
fn hogwild_cell_racing_writers_keep_psi_monotone() {
    // Two writers at stamps 1 and 2: whatever the interleaving, ψ ends
    // at 2 (fetch_max), and reading back pairs a ψ no older than the
    // final weight's stamp. A plain ψ store could end at 1 — a
    // backwards stamp that re-triggers catch-up on a current weight.
    model(|| {
        let cell = Arc::new(HogwildCell::new(0.0));
        let (c1, c2) = (Arc::clone(&cell), Arc::clone(&cell));
        let t1 = thread::spawn(move || c1.publish(1, 10.0));
        let t2 = thread::spawn(move || c2.publish(2, 20.0));
        t1.join().unwrap();
        t2.join().unwrap();
        let (w, psi) = cell.read();
        assert_eq!(psi, 2, "fetch_max must keep the larger stamp");
        assert!(w == 10.0 || w == 20.0);
    });
}

#[test]
fn hogwild_cell_quiescent_reset_is_exact_once_writers_joined() {
    model(|| {
        let cell = Arc::new(HogwildCell::new(0.0));
        let c2 = Arc::clone(&cell);
        let t = thread::spawn(move || c2.publish(3, 7.0));
        t.join().unwrap();
        // Coordinator between barriers: writers joined, plain reads are
        // exact and reset restarts the stamps.
        assert_eq!(cell.value(), 7.0);
        assert_eq!(cell.stamp(), 3);
        cell.reset(7.5);
        assert_eq!(cell.read(), (7.5, 0));
    });
}

// ------------------------------------------------- explorer sanity (meta)

#[test]
fn explorer_still_catches_a_seeded_ordering_bug() {
    // Meta-check that the model harness is alive in this build: the
    // store-before-stamp order (the pre-audit protocol) must FAIL.
    let caught = catch_unwind(AssertUnwindSafe(|| {
        model(|| {
            let w = Arc::new(lazyreg::sync::atomic::AtomicU64::new(1f64.to_bits()));
            let psi = Arc::new(lazyreg::sync::atomic::AtomicU32::new(0));
            let (w2, p2) = (Arc::clone(&w), Arc::clone(&psi));
            let t = thread::spawn(move || {
                w2.store(2f64.to_bits(), Ordering::SeqCst); // weight first: bad
                p2.store(1, Ordering::SeqCst);
            });
            let seen_w = f64::from_bits(w.load(Ordering::SeqCst));
            let seen_psi = psi.load(Ordering::SeqCst);
            t.join().unwrap();
            assert!(!(seen_w == 2.0 && seen_psi < 1));
        });
    }));
    assert!(caught.is_err(), "explorer missed the seeded double-catch-up bug");
}
