//! The penalty-API acceptance suite: the generic law harness
//! (`testing::penalty_laws`) run over **every** registered family —
//! elastic net (with its l1/l22/none degenerate points), truncated
//! gradient, and the ℓ∞ ball — × both update algorithms × all five
//! learning-rate schedules; plus trainer-level lazy ≡ dense and
//! rebase-invisibility properties for the new families, and a
//! medline-shaped end-to-end run showing truncated gradient reaches
//! elastic-net-class sparsity and accuracy through the standard
//! `train_lazy` driver.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::data::CsrMatrix;
use lazyreg::eval::evaluate;
use lazyreg::optim::{Algo, ElasticNet, Linf, Penalty, Regularizer, Schedule, TruncatedGradient};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::testing::penalty_laws::check_penalty_family;
use lazyreg::testing::{property, Gen};
use lazyreg::train::{train_lazy, DenseTrainer, LazyTrainer, TrainOptions};
use lazyreg::util::Rng;

/// The five schedule families, in the stable regime the equivalence
/// tests use elsewhere (SGD validity: max eta0 * max lam2 < 1).
fn schedules() -> [Schedule; 5] {
    [
        Schedule::Constant { eta0: 0.4 },
        Schedule::InvT { eta0: 0.9 },
        Schedule::InvSqrtT { eta0: 0.7 },
        Schedule::Exponential { eta0: 0.5, gamma: 0.97 },
        Schedule::Step { eta0: 0.5, every: 7, factor: 0.5 },
    ]
}

#[test]
fn catchup_laws_hold_for_every_family_algo_schedule() {
    // Concrete family types through the generic harness: elastic net and
    // its degenerate points…
    let elastic = [
        ElasticNet::default(),           // none
        ElasticNet::new(0.01, 0.0),      // l1
        ElasticNet::new(0.0, 0.4),       // l22
        ElasticNet::new(0.02, 0.3),      // enet
    ];
    // …and the two families the penalty API opens.
    let tg = [
        TruncatedGradient::new(0.01, 5, 0.5),
        TruncatedGradient::new(0.02, 1, f64::INFINITY), // degenerate per-step l1
        TruncatedGradient::new(0.05, 13, 2.0),
    ];
    let linf = [Linf::new(0.7), Linf::new(0.05)];

    for algo in [Algo::Sgd, Algo::Fobos] {
        for schedule in schedules() {
            for p in elastic {
                check_penalty_family(p, algo, schedule, 12);
            }
            for p in tg {
                check_penalty_family(p, algo, schedule, 12);
            }
            for p in linf {
                check_penalty_family(p, algo, schedule, 12);
            }
        }
    }
}

#[test]
fn catchup_laws_hold_through_the_enum_dispatch() {
    // The same laws through the trainers' enum (`Regularizer` implements
    // `Penalty` by delegation, so one call per family suffices).
    for reg in [
        Regularizer::elastic_net(0.02, 0.3),
        Regularizer::truncated_gradient(0.01, 5, 0.5),
        Regularizer::linf(0.7),
    ] {
        for algo in [Algo::Sgd, Algo::Fobos] {
            check_penalty_family(reg, algo, Schedule::InvSqrtT { eta0: 0.7 }, 15);
        }
    }
}

/// A random sparse corpus (mirrors `property_equivalence.rs`).
fn random_corpus(n: usize, d: usize, p: usize, rng: &mut Rng) -> (CsrMatrix, Vec<f32>) {
    let mut x = CsrMatrix::empty(d);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let k = 1 + rng.index(p.min(d - 1));
        let cols = rng.sample_distinct(d, k);
        x.push_row(
            cols.into_iter()
                .map(|c| (c as u32, 1.0 + rng.index(3) as f32))
                .collect(),
        );
        ys.push(rng.index(2) as f32);
    }
    (x, ys)
}

/// Draw a random penalty from *any* registered family.
fn random_any_penalty(g: &mut Gen) -> Regularizer {
    match g.usize_in(0, 3) {
        0 => Regularizer::elastic_net(g.f64_in(0.0, 0.02), g.f64_in(0.0, 0.4)),
        1 => Regularizer::truncated_gradient(
            g.f64_in(0.001, 0.05),
            g.usize_in(1, 12) as u64,
            if g.bool(0.3) { f64::INFINITY } else { g.f64_in(0.2, 2.0) },
        ),
        2 => Regularizer::linf(g.f64_in(0.1, 1.0)),
        _ => Regularizer::none(),
    }
}

fn random_schedule(g: &mut Gen) -> Schedule {
    match g.usize_in(0, 4) {
        0 => Schedule::Constant { eta0: g.f64_in(0.02, 0.15) },
        1 => Schedule::InvT { eta0: g.f64_in(0.3, 0.9) },
        2 => Schedule::InvSqrtT { eta0: g.f64_in(0.3, 0.7) },
        3 => Schedule::Exponential { eta0: g.f64_in(0.2, 0.5), gamma: 0.99 },
        _ => Schedule::Step { eta0: g.f64_in(0.2, 0.5), every: 13, factor: 0.5 },
    }
}

#[test]
fn lazy_trainer_equals_dense_trainer_for_every_family() {
    property("lazy == dense (any penalty family)", 30, |g| {
        let opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg: random_any_penalty(g),
            schedule: random_schedule(g),
            ..Default::default()
        };
        let mut rng = Rng::new(0x9E4A_u64.wrapping_add(g.case as u64 * 0x7F4A));
        let d = g.usize_in(8, 50);
        let n = g.usize_in(10, 140);
        let (x, ys) = random_corpus(n, d, 8, &mut rng);

        let mut lazy = LazyTrainer::new(d, &opts);
        let mut dense = DenseTrainer::new(d, &opts);
        for (r, &y) in ys.iter().enumerate() {
            lazy.process_example(x.row(r), f64::from(y));
            dense.process_example(x.row(r), f64::from(y));
        }
        lazy.finalize();
        let diff = lazy.model().max_weight_diff(dense.model());
        assert!(diff < 1e-9, "weight diff {diff} ({})", opts.reg.name());
    });
}

#[test]
fn rebase_is_invisible_through_the_trainer_for_new_families() {
    property("tiny budget == default budget (tg, linf)", 20, |g| {
        let reg = if g.bool(0.5) {
            Regularizer::truncated_gradient(g.f64_in(0.005, 0.05), g.usize_in(1, 8) as u64, 1.0)
        } else {
            Regularizer::linf(g.f64_in(0.2, 1.0))
        };
        let opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg,
            schedule: Schedule::InvSqrtT { eta0: 0.5 },
            ..Default::default()
        };
        let mut tiny = opts;
        tiny.space_budget = Some(g.usize_in(4, 64));

        let mut rng = Rng::new(0x7AB_u64.wrapping_add(g.case as u64 * 0x51D));
        let d = g.usize_in(10, 40);
        let (x, ys) = random_corpus(150, d, 6, &mut rng);

        let mut budgeted = LazyTrainer::new(d, &tiny);
        let mut default = LazyTrainer::new(d, &opts);
        for (r, &y) in ys.iter().enumerate() {
            budgeted.process_example(x.row(r), f64::from(y));
            default.process_example(x.row(r), f64::from(y));
        }
        assert!(budgeted.rebases > 0, "no rebase with budget {:?}", tiny.space_budget);
        assert_eq!(default.rebases, 0);
        budgeted.finalize();
        default.finalize();
        let diff = budgeted.model().max_weight_diff(default.model());
        assert!(diff < 1e-9, "rebase changed semantics: diff {diff} ({})", reg.name());
    });
}

fn medline_small() -> lazyreg::data::SparseDataset {
    generate(
        &BowSpec { n_examples: 1_500, n_features: 8_000, avg_nnz: 50.0, ..Default::default() },
        1234,
    )
}

#[test]
fn truncated_gradient_matches_elastic_net_class_results_on_medline_small() {
    // Satellite acceptance: truncated gradient through the standard
    // `train_lazy` driver reaches sparsity/accuracy comparable to
    // elastic net on the medline-shaped corpus.
    let data = medline_small();
    let (train, test) = data.split(0.3, 5);
    let base = TrainOptions {
        algo: Algo::Fobos,
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 3,
        ..Default::default()
    };

    let mut unreg = base;
    unreg.reg = Regularizer::none();
    let mut enet = base;
    enet.reg = Regularizer::elastic_net(5e-3, 1e-3);
    let mut tg = base;
    tg.reg = Regularizer::truncated_gradient(5e-3, 10, f64::INFINITY);

    let r_unreg = train_lazy(&train, &unreg).unwrap();
    let r_enet = train_lazy(&train, &enet).unwrap();
    let r_tg = train_lazy(&train, &tg).unwrap();
    assert_eq!(r_tg.penalty, "tg:0.005:10:inf");

    let nnz_unreg = r_unreg.model.sparsity().nnz;
    let nnz_enet = r_enet.model.sparsity().nnz;
    let nnz_tg = r_tg.model.sparsity().nnz;
    // Both regularizers prune a large fraction of the touched weights…
    assert!(nnz_enet * 2 < nnz_unreg, "enet {nnz_enet} vs unreg {nnz_unreg}");
    assert!(nnz_tg * 2 < nnz_unreg, "tg {nnz_tg} vs unreg {nnz_unreg}");
    // …and tg sparsity is in the same class as elastic net's (the same
    // total gravity is applied, just at K-step boundaries).
    assert!(
        nnz_tg < nnz_enet * 4 && nnz_enet < nnz_tg * 4,
        "sparsity not comparable: tg {nnz_tg} vs enet {nnz_enet}"
    );

    let (acc_enet, _) = evaluate(&r_enet.model, &test);
    let (acc_tg, _) = evaluate(&r_tg.model, &test);
    assert!(
        (acc_tg.accuracy - acc_enet.accuracy).abs() < 0.05,
        "accuracy diverged: tg {} vs enet {}",
        acc_tg.accuracy,
        acc_enet.accuracy
    );
    assert!(r_tg.final_loss() < r_tg.epochs[0].mean_loss, "tg loss did not improve");
}

#[test]
fn linf_ball_constrains_weights_end_to_end() {
    let data = medline_small();
    let radius = 0.05;
    let opts = TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::linf(radius),
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 2,
        ..Default::default()
    };
    let report = train_lazy(&data, &opts).unwrap();
    let sp = report.model.sparsity();
    assert!(
        sp.max_abs <= radius + 1e-12,
        "weights escaped the ball: {} > {radius}",
        sp.max_abs
    );
    assert!(report.final_loss() < report.epochs[0].mean_loss, "linf loss did not improve");
    assert_eq!(report.penalty, format!("linf:{radius}"));
    assert_eq!(report.model.penalty.as_deref(), Some(format!("linf:{radius}").as_str()));
}

#[test]
fn penalty_value_is_exposed_for_objective_logging() {
    let w = [0.5, -0.25, 0.0];
    assert!((Regularizer::l1(0.1).penalty(&w) - 0.075).abs() < 1e-12);
    assert_eq!(Regularizer::linf(1.0).penalty(&w), 0.0);
    let tg = Regularizer::truncated_gradient(0.1, 4, 1.0);
    assert!((tg.penalty(&w) - 0.075).abs() < 1e-12);
    // And through the trait, for generic code.
    assert_eq!(Penalty::value(&Regularizer::none(), &w), 0.0);
}
