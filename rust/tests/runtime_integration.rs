//! Integration tests over the PJRT runtime: the AOT artifacts (Layer 2
//! jax graph + Layer 1 Pallas kernels, compiled by `make artifacts`) must
//! agree numerically with the native Rust implementations.
//!
//! These tests SKIP (pass trivially, with a note) when `artifacts/` is
//! missing so `cargo test` works before the Python toolchain has run;
//! `make test` always builds artifacts first.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::data::BatchIter;
use lazyreg::loss::sigmoid;
use lazyreg::optim::{Algo, DpCache, Regularizer, Schedule};
use lazyreg::runtime::{Runtime, XlaDenseTrainer};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::util::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::load(&Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e}); run `make artifacts`");
            None
        }
    }
}

fn corpus(dim: usize) -> lazyreg::data::SparseDataset {
    generate(
        &BowSpec { n_examples: 600, n_features: dim, avg_nnz: 50.0, ..Default::default() },
        77,
    )
}

#[test]
fn predict_artifact_matches_native_scoring() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let data = corpus(meta.dim);
    let mut rng = Rng::new(5);
    let w: Vec<f32> = (0..meta.dim).map(|_| rng.normal_ms(0.0, 0.05) as f32).collect();
    let bias = 0.125f32;

    let batch = BatchIter::new(&data, meta.batch, meta.dim).next().unwrap();
    let probs = rt.predict(&batch.x, &w, bias).unwrap();
    assert_eq!(probs.len(), meta.batch);
    for b in 0..batch.len {
        let mut z = f64::from(bias);
        for j in 0..meta.dim {
            z += f64::from(batch.x[b * meta.dim + j]) * f64::from(w[j]);
        }
        let want = sigmoid(z);
        assert!(
            (want - f64::from(probs[b])).abs() < 1e-4,
            "row {b}: native {want} vs xla {}",
            probs[b]
        );
    }
}

#[test]
fn grad_artifact_matches_finite_difference() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let data = corpus(meta.dim);
    let mut rng = Rng::new(6);
    let w: Vec<f32> = (0..meta.dim).map(|_| rng.normal_ms(0.0, 0.02) as f32).collect();
    let bias = 0.0f32;
    let batch = BatchIter::new(&data, meta.batch, meta.dim).next().unwrap();

    let (loss, gw, gb) = rt.grad(&batch.x, &batch.y, &w, bias).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert_eq!(gw.len(), meta.dim);

    // Finite-difference on the bias (cheap, O(1) artifact calls).
    let h = 1e-3f32;
    let (loss_p, _, _) = rt.grad(&batch.x, &batch.y, &w, bias + h).unwrap();
    let (loss_m, _, _) = rt.grad(&batch.x, &batch.y, &w, bias - h).unwrap();
    let fd = (f64::from(loss_p) - f64::from(loss_m)) / (2.0 * f64::from(h));
    assert!(
        (fd - f64::from(gb)).abs() < 5e-3,
        "gb {gb} vs finite-diff {fd}"
    );
}

#[test]
fn fobos_step_artifact_matches_native_dense_math() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let data = corpus(meta.dim);
    let batch = BatchIter::new(&data, meta.batch, meta.dim).next().unwrap();
    let mut rng = Rng::new(7);
    let w: Vec<f32> = (0..meta.dim).map(|_| rng.normal_ms(0.0, 0.02) as f32).collect();
    let (bias, eta, lam1, lam2) = (0.05f32, 0.1f32, 1e-3f32, 1e-2f32);

    let (w2, b2, loss) = rt.fobos_step(&batch.x, &batch.y, &w, bias, eta, lam1, lam2).unwrap();
    assert!(loss.is_finite());

    // Native recomputation in f64.
    let n = meta.batch as f64;
    let mut gw = vec![0.0f64; meta.dim];
    let mut gb = 0.0f64;
    for b in 0..meta.batch {
        let mut z = f64::from(bias);
        for j in 0..meta.dim {
            z += f64::from(batch.x[b * meta.dim + j]) * f64::from(w[j]);
        }
        let r = (sigmoid(z) - f64::from(batch.y[b])) / n;
        for j in 0..meta.dim {
            let x = f64::from(batch.x[b * meta.dim + j]);
            if x != 0.0 {
                gw[j] += x * r;
            }
        }
        gb += r;
    }
    let mut max_diff = (f64::from(b2) - (f64::from(bias) - f64::from(eta) * gb)).abs();
    for j in 0..meta.dim {
        let wh = f64::from(w[j]) - f64::from(eta) * gw[j];
        let mag = (wh.abs() - f64::from(eta) * f64::from(lam1))
            / (1.0 + f64::from(eta) * f64::from(lam2));
        let want = wh.signum() * mag.max(0.0);
        let want = if wh == 0.0 { 0.0 } else { want };
        max_diff = max_diff.max((want - f64::from(w2[j])).abs());
    }
    assert!(max_diff < 1e-4, "fobos_step max diff {max_diff}");
}

#[test]
fn catchup_artifact_matches_dp_cache() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let steps = (meta.table - 1).min(2_000);
    let mut cache = DpCache::new(
        Algo::Fobos,
        Regularizer::elastic_net(1e-3, 1e-2),
        Schedule::InvSqrtT { eta0: 0.5 },
    );
    for _ in 0..steps {
        cache.step();
    }
    let (pt, bt) = cache.tables();
    let mut pt32: Vec<f32> = pt.iter().map(|&x| x as f32).collect();
    let mut bt32: Vec<f32> = bt.iter().map(|&x| x as f32).collect();
    pt32.resize(meta.table, 1.0);
    bt32.resize(meta.table, 0.0);

    let mut rng = Rng::new(8);
    let w: Vec<f64> = (0..meta.catchup_dim).map(|_| rng.normal_ms(0.0, 0.5)).collect();
    let psi: Vec<u32> = (0..meta.catchup_dim).map(|_| rng.index(steps + 1) as u32).collect();
    let w32: Vec<f32> = w.iter().map(|&x| x as f32).collect();
    let psi32: Vec<i32> = psi.iter().map(|&p| p as i32).collect();

    let lam1 = cache.penalty().as_elastic_net().expect("elastic-net cache").lam1 as f32;
    let got = rt.catchup(&w32, &psi32, &pt32, &bt32, steps as i32, lam1).unwrap();
    let mut max_diff = 0.0f64;
    for j in 0..meta.catchup_dim {
        let want = cache.catchup(w[j], psi[j]);
        max_diff = max_diff.max((want - f64::from(got[j])).abs());
    }
    assert!(max_diff < 1e-4, "catchup artifact max diff {max_diff} (f32)");
}

#[test]
fn xla_dense_trainer_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta();
    let data = corpus(meta.dim);
    // Modest eta0: with count-valued BoW features the logistic gradients
    // are large and eta0 = 0.5 diverges on 256-example batches.
    let mut t = XlaDenseTrainer::new(&rt, 1e-6, 1e-6, 0.05);
    let r1 = t.train(&data, 1).unwrap();
    let r2 = t.train(&data, 1).unwrap();
    assert!(r2.final_loss < r1.final_loss, "{} -> {}", r1.final_loss, r2.final_loss);
    assert!(r1.examples_per_sec > 0.0);
}
