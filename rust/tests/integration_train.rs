//! Integration tests across the data → synth → train → eval stack
//! (no artifacts required; see runtime_integration.rs for the PJRT path).


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::coordinator::{train_one_vs_rest, train_streaming};
use lazyreg::data::libsvm;
use lazyreg::eval::evaluate;
use lazyreg::prelude::*;
use lazyreg::synth::{generate, BowSpec};
use lazyreg::testing::agrees_to_sig_figs;

fn medline_small() -> lazyreg::data::SparseDataset {
    generate(
        &BowSpec { n_examples: 1_500, n_features: 8_000, avg_nnz: 50.0, ..Default::default() },
        1234,
    )
}

fn opts() -> TrainOptions {
    TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-5, 1e-5),
        schedule: Schedule::InvSqrtT { eta0: 0.5 },
        epochs: 2,
        shuffle: false,
        ..Default::default()
    }
}

#[test]
fn end_to_end_lazy_equals_dense_on_medline_shape() {
    let data = medline_small();
    let lazy = train_lazy(&data, &opts()).unwrap();
    let dense = train_dense(&data, &opts()).unwrap();
    let diff = lazy.model.max_weight_diff(&dense.model);
    assert!(diff < 1e-9, "lazy vs dense diff {diff}");
    for (a, b) in lazy.model.weights.iter().zip(dense.model.weights.iter()) {
        assert!(agrees_to_sig_figs(*a, *b, 4), "{a} vs {b}"); // paper's criterion
    }
}

#[test]
fn end_to_end_learns_signal_above_chance() {
    let data = medline_small();
    let (train, test) = data.split(0.3, 5);
    let mut o = opts();
    o.epochs = 4;
    o.shuffle = true;
    let report = train_lazy(&train, &o).unwrap();
    let (at_half, best) = evaluate(&report.model, &test);
    // teacher-labeled corpus: must beat the majority-class baseline
    let pos = test.stats().positive_rate;
    let majority = pos.max(1.0 - pos);
    assert!(
        at_half.accuracy > majority + 0.03,
        "acc {} <= majority {majority}",
        at_half.accuracy
    );
    assert!(best.f1 > 0.5, "F1* {}", best.f1);
    // loss curve decreasing
    assert!(report.final_loss() < report.epochs[0].mean_loss);
}

#[test]
fn libsvm_round_trip_preserves_training_result() {
    let data = medline_small();
    let mut buf: Vec<u8> = Vec::new();
    libsvm::write(&mut buf, &data).unwrap();
    // We wrote the file ourselves (1-based by contract): pin the base
    // rather than letting Auto re-guess it from the index range.
    let data2 = libsvm::read_with(
        buf.as_slice(),
        Some(data.n_features()),
        libsvm::IndexBase::One,
    )
    .unwrap();
    assert_eq!(data.x(), data2.x());
    let a = train_lazy(&data, &opts()).unwrap();
    let b = train_lazy(&data2, &opts()).unwrap();
    assert_eq!(a.model.weights, b.model.weights);
}

#[test]
fn streaming_pipeline_matches_in_memory_single_epoch() {
    let data = medline_small();
    let mut buf: Vec<u8> = Vec::new();
    libsvm::write(&mut buf, &data).unwrap();

    let mut o = opts();
    o.epochs = 1;
    o.shuffle = false;
    let (stream_model, stats) =
        train_streaming(buf.as_slice(), data.n_features(), &o, 64).unwrap();
    assert_eq!(stats.examples as usize, data.n_examples());
    assert_eq!(stats.parse_errors, 0);

    let in_memory = train_lazy(&data, &o).unwrap();
    let mut max_diff = (stream_model.bias - in_memory.model.bias).abs();
    for (a, b) in stream_model.weights.iter().zip(in_memory.model.weights.iter()) {
        max_diff = max_diff.max((a - b).abs());
    }
    // f32 values survive libsvm text exactly (printed via {}); training is
    // identical modulo f64 ops on identical inputs.
    assert!(max_diff < 1e-9, "stream vs memory diff {max_diff}");
}

#[test]
fn streaming_with_merge_none_falls_back_to_flat_and_learns() {
    // The lock-free pool needs the whole corpus up front (shared weight
    // vector + round pre-extension); the streaming coordinator logs a
    // fallback and runs its usual end-of-stream flat merge instead.
    let data = medline_small();
    let mut buf: Vec<u8> = Vec::new();
    libsvm::write(&mut buf, &data).unwrap();
    let mut o = opts();
    o.epochs = 1;
    o.shuffle = false;
    o.workers = 2;
    o.merge = lazyreg::train::MergeMode::None;
    let (model, stats) = train_streaming(buf.as_slice(), data.n_features(), &o, 64).unwrap();
    assert_eq!(stats.examples as usize, data.n_examples());
    assert_eq!(stats.parse_errors, 0);
    assert!(stats.mean_loss.is_finite());
    assert!(model.weights.iter().any(|&w| w != 0.0), "fallback produced a zero model");
}

#[test]
fn one_vs_rest_coordinator_end_to_end() {
    let data = medline_small();
    let x = data.x();
    // Two derived tags: presence of any feature < 100; original labels.
    let tag0: Vec<f32> = (0..x.n_rows())
        .map(|r| x.row(r).indices.iter().any(|&j| j < 100) as u8 as f32)
        .collect();
    let tag1: Vec<f32> = data.labels().to_vec();
    let tags = vec![tag0.clone(), tag1];
    let mut o = opts();
    o.epochs = 3;
    let report = train_one_vs_rest(x, &tags, &o, 2).unwrap();
    assert_eq!(report.models.len(), 2);
    // tag0 is perfectly predictable from features
    let p: Vec<f64> = (0..x.n_rows()).map(|r| report.models[0].predict(x.row(r))).collect();
    let m = lazyreg::eval::optimal_f1(&p, &tag0);
    assert!(m.f1 > 0.9, "tag0 F1 {}", m.f1);
}

#[test]
fn sgd_and_fobos_both_converge_same_data() {
    let data = medline_small();
    for algo in [Algo::Sgd, Algo::Fobos] {
        let o = TrainOptions { algo, epochs: 3, ..opts() };
        let report = train_lazy(&data, &o).unwrap();
        assert!(
            report.final_loss() < report.epochs[0].mean_loss,
            "{algo:?} did not improve"
        );
    }
}

#[test]
fn space_budget_flushes_do_not_change_end_to_end_result() {
    let data = medline_small();
    let baseline = train_lazy(&data, &opts()).unwrap();
    let mut tiny = opts();
    tiny.space_budget = Some(128); // ~23 flushes over 3000 iterations
    let flushed = train_lazy(&data, &tiny).unwrap();
    assert!(flushed.rebases > 5);
    let diff = baseline.model.max_weight_diff(&flushed.model);
    assert!(diff < 1e-9, "budget changed semantics: {diff}");
}
