//! Integration tests for the serving stack: protocol error paths,
//! sharded-vs-native bitwise score parity, hot model reload, and
//! connection-churn behavior of the fixed worker pool.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use lazyreg::data::RowView;
use lazyreg::loss::Loss;
use lazyreg::model::LinearModel;
use lazyreg::predict::{Predictor, ShardedModel, SCORE_BLOCK};
use lazyreg::serve::{Client, ServeOptions, Server};
use lazyreg::util::Rng;

fn model(dim: usize, seed: u64) -> LinearModel {
    let mut m = LinearModel::zeros(dim, Loss::Logistic);
    let mut rng = Rng::new(seed);
    for w in m.weights.iter_mut() {
        if rng.bool(0.05) {
            *w = rng.normal();
        }
    }
    m.bias = rng.normal() * 0.1;
    m
}

/// Send one raw protocol line and read one reply line.
fn raw_round_trip(addr: std::net::SocketAddr, line: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

#[test]
fn sharded_scores_bitwise_match_native_across_shard_counts() {
    let dim = 5 * SCORE_BLOCK as usize + 321;
    let m = model(dim, 2);
    let mut rng = Rng::new(40);
    let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..32)
        .map(|_| {
            let nnz = 1 + rng.index(200);
            let idx = rng.sample_distinct(dim, nnz);
            idx.into_iter().map(|j| (j as u32, rng.normal() as f32)).unzip()
        })
        .collect();
    let views: Vec<RowView<'_>> =
        rows.iter().map(|(i, v)| RowView { indices: i, values: v }).collect();
    let native = Predictor::score_batch(&m, &views);
    for shards in [1usize, 2, 7] {
        let sharded = ShardedModel::spawn(&m, shards, 1);
        let got = sharded.score_batch(&views);
        assert_eq!(got.len(), native.len());
        for (r, (a, b)) in native.iter().zip(got.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "shards={shards} row={r}: native={a} sharded={b}"
            );
        }
    }
}

#[test]
fn protocol_error_paths() {
    let server = Server::spawn(model(10, 3), "127.0.0.1:0").unwrap();
    let addr = server.addr();
    // Unknown command.
    assert_eq!(raw_round_trip(addr, "frobnicate"), "err unknown-command");
    // A command prefix without its delimiter is not that command.
    assert_eq!(raw_round_trip(addr, "predictions 3:1"), "err unknown-command");
    assert_eq!(raw_round_trip(addr, "reloadable"), "err unknown-command");
    // Out-of-range feature index.
    assert_eq!(raw_round_trip(addr, "predict 99:1"), "err bad-features");
    // Malformed value.
    assert_eq!(raw_round_trip(addr, "predict 1:abc"), "err bad-features");
    // Bad example inside a batch poisons the whole batch.
    assert_eq!(raw_round_trip(addr, "batch 1:1;2:bad"), "err bad-features");
    // Reload of a nonexistent file fails without killing the server.
    let reply = raw_round_trip(addr, "reload /nonexistent/path.model");
    assert!(reply.starts_with("err reload-failed"), "{reply}");
    // Duplicate indices are merged (summed), upholding the sorted
    // strictly-increasing RowView invariant even under --shards.
    let dup = raw_round_trip(addr, "predict 3:1 3:1");
    let merged = raw_round_trip(addr, "predict 3:2");
    assert_eq!(dup, merged, "duplicates must score like their sum");
    assert!(dup.starts_with("ok "), "{dup}");
    // The server still answers after all of the above.
    let mut c = Client::connect(addr).unwrap();
    assert!(c.predict(&[]).is_ok());
    c.quit().unwrap();
    server.shutdown();
}

#[test]
fn mid_line_disconnect_does_not_wedge_a_worker() {
    // A single-worker pool: if the dropped connection wedged the worker,
    // the follow-up client could never be served.
    let opts = ServeOptions { workers: 1, ..Default::default() };
    let server = Server::spawn_with(model(10, 4), "127.0.0.1:0", opts).unwrap();
    let addr = server.addr();
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        // Partial line: no trailing newline, then hang up.
        stream.write_all(b"predict 1:1").unwrap();
    }
    let mut c = Client::connect(addr).unwrap();
    assert!(c.predict(&[(1, 1.0)]).is_ok());
    c.quit().unwrap();
    server.shutdown();
}

#[test]
fn hot_reload_bumps_version_and_swaps_weights() {
    let dir = std::env::temp_dir();
    let path_b = dir.join("lazyreg_serve_reload_b.model");
    let mut a = LinearModel::zeros(10, Loss::Logistic);
    a.weights[3] = 2.0;
    let mut b = LinearModel::zeros(10, Loss::Logistic);
    b.weights[3] = -2.0;
    lazyreg::model::io::save(&path_b, &b).unwrap();

    let opts = ServeOptions { shards: 2, ..Default::default() };
    let server = Server::spawn_with(a, "127.0.0.1:0", opts).unwrap();
    assert_eq!(server.version(), 1);
    let mut c = Client::connect(server.addr()).unwrap();
    assert!(c.stats().unwrap().contains("version=1"));
    let before = c.predict(&[(3, 1.0)]).unwrap();
    assert!(before > 0.8, "{before}");

    let v = c.reload(path_b.to_str().unwrap()).unwrap();
    assert_eq!(v, 2);
    assert_eq!(server.version(), 2);
    // The same connection now scores with the new weights.
    let after = c.predict(&[(3, 1.0)]).unwrap();
    assert!(after < 0.2, "{after}");
    assert!(c.stats().unwrap().contains("version=2"));

    // Reloads are monotonic.
    assert_eq!(c.reload(path_b.to_str().unwrap()).unwrap(), 3);
    c.quit().unwrap();
    server.shutdown();
    std::fs::remove_file(&path_b).ok();
}

#[test]
fn connection_churn_is_reaped_by_the_fixed_pool() {
    let opts = ServeOptions { workers: 2, ..Default::default() };
    let server = Server::spawn_with(model(10, 5), "127.0.0.1:0", opts).unwrap();
    assert_eq!(server.worker_count(), 2);
    let addr = server.addr();
    // 50 sequential connections: under the seed's thread-per-connection
    // design this accumulated 50 JoinHandles; the pool handles them with
    // 2 threads and stays responsive.
    for i in 0..50 {
        let mut c = Client::connect(addr).unwrap();
        let p = c.predict(&[(1, i as f32)]).unwrap();
        assert!((0.0..=1.0).contains(&p));
        c.quit().unwrap();
    }
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    // conns counts every accepted connection, proving the pool (not a
    // thread spawn) served the churn.
    let conns: u64 = stats
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("conns="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    assert!(conns >= 50, "{stats}");
    c.quit().unwrap();
    server.shutdown();
}

#[test]
fn batch_round_trip_matches_native_predictions() {
    let dim = 2 * SCORE_BLOCK as usize + 7;
    let m = model(dim, 6);
    let mut rng = Rng::new(8);
    let examples: Vec<Vec<(u32, f32)>> = (0..9)
        .map(|_| {
            let idx = rng.sample_distinct(dim, 30);
            idx.into_iter().map(|j| (j as u32, rng.normal() as f32)).collect()
        })
        .collect();
    let opts = ServeOptions { shards: 3, ..Default::default() };
    let server = Server::spawn_with(m.clone(), "127.0.0.1:0", opts).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let got = c.predict_batch(&examples).unwrap();
    for (ex, &p) in examples.iter().zip(got.iter()) {
        let (indices, values): (Vec<u32>, Vec<f32>) = ex.iter().copied().unzip();
        let native = Predictor::predict(&m, RowView { indices: &indices, values: &values });
        // The wire format rounds to 6 decimals.
        assert!((p - native).abs() < 1e-6, "p={p} native={native}");
    }
    c.quit().unwrap();
    server.shutdown();
}
