//! Property-test suite for the paper's equivalence claims, built on the
//! from-scratch `testing::property` harness:
//!
//! * lazy O(p) training == dense O(d) training over random corpora,
//!   schedules (all five families), regularizers (none / ℓ1 / ℓ2² /
//!   elastic net) and both update algorithms (SGD, FoBoS), to the
//!   paper's §7 criterion (4 significant figures) and far tighter in
//!   absolute terms;
//! * DP-cache rebase invisibility: a forced tiny space budget (4–64
//!   slots, i.e. many amortized flushes) changes nothing about the final
//!   model;
//! * the data-parallel engine with `workers = 1` is bit-identical to the
//!   serial lazy trainer.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::data::CsrMatrix;
use lazyreg::optim::{Algo, Regularizer, Schedule};
use lazyreg::testing::{agrees_to_sig_figs, property, Gen};
use lazyreg::train::{
    train_parallel_dense_xy, train_parallel_xy, DenseTrainer, LazyTrainer, TrainOptions, Trainer,
};
use lazyreg::util::Rng;

/// A random sparse corpus: `n` rows of up to `p` features out of `d`,
/// values in {1, 2, 3} (bag-of-words-like counts), labels in {0, 1}.
fn random_corpus(n: usize, d: usize, p: usize, rng: &mut Rng) -> (CsrMatrix, Vec<f32>) {
    let mut x = CsrMatrix::empty(d);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let k = 1 + rng.index(p.min(d - 1));
        let cols = rng.sample_distinct(d, k);
        x.push_row(
            cols.into_iter()
                .map(|c| (c as u32, 1.0 + rng.index(3) as f32))
                .collect(),
        );
        ys.push(rng.index(2) as f32);
    }
    (x, ys)
}

/// Draw a random schedule whose dynamics stay in the stable regime
/// (large constant rates on count-valued features amplify 1e-15
/// rounding chaotically — see the note in `benches/equivalence_report`).
fn random_schedule(g: &mut Gen) -> Schedule {
    match g.usize_in(0, 4) {
        0 => Schedule::Constant { eta0: g.f64_in(0.02, 0.15) },
        1 => Schedule::InvT { eta0: g.f64_in(0.3, 0.9) },
        2 => Schedule::InvSqrtT { eta0: g.f64_in(0.3, 0.7) },
        3 => Schedule::Exponential { eta0: g.f64_in(0.2, 0.5), gamma: 0.99 },
        _ => Schedule::Step { eta0: g.f64_in(0.2, 0.5), every: 13, factor: 0.5 },
    }
}

/// Draw a random regularizer; `eta0 * lam2 < 1` holds for every schedule
/// above (max eta0 = 0.9, max lam2 = 0.4), so SGD stays valid.
fn random_reg(g: &mut Gen) -> Regularizer {
    let lam1 = if g.bool(0.25) { 0.0 } else { g.f64_in(0.0, 0.02) };
    let lam2 = if g.bool(0.25) { 0.0 } else { g.f64_in(0.0, 0.4) };
    Regularizer::elastic_net(lam1, lam2)
}

#[test]
fn lazy_equals_dense_over_random_configurations() {
    property("lazy == dense (random schedule x reg x algo)", 30, |g| {
        let opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg: random_reg(g),
            schedule: random_schedule(g),
            ..Default::default()
        };
        let mut rng = Rng::new(0xE_9_u64.wrapping_add(g.case as u64 * 0x9E37));
        let d = g.usize_in(8, 60);
        let n = g.usize_in(10, 150);
        let (x, ys) = random_corpus(n, d, 8, &mut rng);

        let mut lazy = LazyTrainer::new(d, &opts);
        let mut dense = DenseTrainer::new(d, &opts);
        for (r, &y) in ys.iter().enumerate() {
            let l1 = lazy.process_example(x.row(r), f64::from(y));
            let l2 = dense.process_example(x.row(r), f64::from(y));
            assert!(
                agrees_to_sig_figs(l1, l2, 6),
                "losses diverge at step {r}: {l1} vs {l2}"
            );
        }
        lazy.finalize();
        let diff = lazy.model().max_weight_diff(dense.model());
        assert!(diff < 1e-7, "weight diff {diff} ({opts:?})");
        // The paper's §7 criterion (relative comparison is meaningless at
        // the float-cancellation floor; those weights are covered by the
        // absolute bound above).
        for (a, b) in lazy
            .model()
            .weights
            .iter()
            .zip(dense.model().weights.iter())
        {
            if a.abs().max(b.abs()) > 1e-10 {
                assert!(agrees_to_sig_figs(*a, *b, 4), "{a} vs {b}");
            }
        }
    });
}

#[test]
fn dp_cache_rebase_is_semantically_invisible() {
    property("tiny space budget == default budget", 30, |g| {
        let opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg: random_reg(g),
            schedule: random_schedule(g),
            ..Default::default()
        };
        let mut tiny = opts;
        tiny.space_budget = Some(g.usize_in(4, 64));

        let mut rng = Rng::new(0xB0B_u64.wrapping_add(g.case as u64 * 0x5BD1));
        let d = g.usize_in(10, 50);
        let (x, ys) = random_corpus(200, d, 6, &mut rng);

        let mut budgeted = LazyTrainer::new(d, &tiny);
        let mut default = LazyTrainer::new(d, &opts);
        for (r, &y) in ys.iter().enumerate() {
            budgeted.process_example(x.row(r), f64::from(y));
            default.process_example(x.row(r), f64::from(y));
        }
        // 200 steps against a <= 64-slot table must have flushed.
        assert!(budgeted.rebases > 0, "no rebase with budget {:?}", tiny.space_budget);
        assert_eq!(default.rebases, 0);
        budgeted.finalize();
        default.finalize();
        let diff = budgeted.model().max_weight_diff(default.model());
        assert!(diff < 1e-9, "rebase changed semantics: diff {diff}");
    });
}

#[test]
fn parallel_engine_lazy_equals_dense_workers() {
    // The third side of the lazy/dense/parallel triangle: for any worker
    // count and sync cadence, the sharded engine produces the same model
    // whether workers run lazy or dense updates (identical shard + merge
    // schedule; per-worker updates are the paper's exact equivalence).
    property("sharded lazy workers == sharded dense workers", 15, |g| {
        let opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg: random_reg(g),
            schedule: random_schedule(g),
            epochs: g.usize_in(1, 2),
            workers: g.usize_in(2, 4),
            sync_interval: if g.bool(0.5) { Some(g.usize_in(1, 25)) } else { None },
            ..Default::default()
        };
        let mut rng = Rng::new(0xD1CE_u64.wrapping_add(g.case as u64 * 0x6C62));
        let d = g.usize_in(8, 40);
        let (x, ys) = random_corpus(g.usize_in(12, 120), d, 6, &mut rng);

        let lazy = train_parallel_xy(&x, &ys, &opts).unwrap();
        let dense = train_parallel_dense_xy(&x, &ys, &opts).unwrap();
        let diff = lazy.model.max_weight_diff(&dense.model);
        assert!(diff < 1e-8, "parallel lazy vs dense diff {diff} ({opts:?})");
    });
}

#[test]
fn parallel_single_worker_is_bitwise_serial() {
    property("train_parallel(workers=1) == serial lazy", 15, |g| {
        let mut opts = TrainOptions {
            algo: *g.choose(&[Algo::Sgd, Algo::Fobos]),
            reg: random_reg(g),
            schedule: random_schedule(g),
            epochs: g.usize_in(1, 3),
            workers: 1,
            ..Default::default()
        };
        // sync_interval must be irrelevant when workers == 1.
        if g.bool(0.5) {
            opts.sync_interval = Some(g.usize_in(1, 20));
        }
        let mut rng = Rng::new(0xCAFE_u64.wrapping_add(g.case as u64 * 0x41C6));
        let d = g.usize_in(8, 40);
        let (x, ys) = random_corpus(g.usize_in(10, 100), d, 6, &mut rng);

        let par = train_parallel_xy(&x, &ys, &opts).unwrap();

        let mut serial = LazyTrainer::new(d, &opts);
        let mut order_rng = Rng::new(opts.seed);
        for _ in 0..opts.epochs {
            let mut order: Vec<usize> = (0..x.n_rows()).collect();
            if opts.shuffle {
                order_rng.shuffle(&mut order);
            }
            for &r in &order {
                Trainer::process_example(&mut serial, x.row(r), f64::from(ys[r]));
            }
        }
        let serial_model = serial.into_model();
        assert_eq!(par.model.weights, serial_model.weights);
        assert_eq!(par.model.bias, serial_model.bias);
    });
}
