//! The two binary formats (`LZBC` dataset cache, `LZMC` compact model)
//! against their promises, in one process — the format-level sibling of
//! `net_protocol.rs`:
//!
//! * the dataset cache round-trips synthetic corpora of several shapes
//!   exactly, and the cached load equals the libsvm parse it replaces;
//! * corruption of an existing cache file is a structured error, never
//!   a silent re-parse and never a panic;
//! * the compact model artifact round-trips randomized sparse models
//!   bitwise in `f64`, quantizes exactly to `f32` when opted in, and
//!   loads interchangeably with the text format through
//!   `model::io::load`'s magic sniffing;
//! * scoring a compact-round-tripped model through the merge-join
//!   `SparseModel` is bitwise-identical to the dense blocked kernel;
//! * the compact artifact of an ℓ1-sparse model stays under 25% of the
//!   dense weight-dump size (8 bytes × dim) and under the text artifact
//!   it replaces;
//! * v1/v2 text model files still load with correct provenance.

// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::data::{cache, libsvm, RowView, SparseDataset};
use lazyreg::loss::Loss;
use lazyreg::model::{compact, io as model_io, LinearModel};
use lazyreg::predict::{self, Predictor, SparseModel};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::util::Rng;

fn corpus(n: usize, d: usize, p: f64, seed: u64) -> SparseDataset {
    let spec = BowSpec { n_examples: n, n_features: d, avg_nnz: p, ..Default::default() };
    generate(&spec, seed)
}

fn random_model(dim: usize, density: f64, seed: u64) -> LinearModel {
    let mut m = LinearModel::zeros(dim, Loss::Logistic);
    let mut rng = Rng::new(seed);
    for w in m.weights.iter_mut() {
        if rng.bool(density) {
            *w = rng.normal();
        }
    }
    m.bias = rng.normal();
    m.penalty = Some("enet:1e-5:1e-5".into());
    m
}

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lazyreg_codecs_{}_{name}", std::process::id()))
}

// ------------------------------------------------------- dataset cache

#[test]
fn cache_round_trips_corpora_of_several_shapes() {
    for (i, (n, d, p)) in [(1usize, 1usize, 0.5f64), (40, 500, 8.0), (200, 4096, 30.0)]
        .into_iter()
        .enumerate()
    {
        let data = corpus(n, d, p, 100 + i as u64);
        let stamp = cache::SourceStamp { len: 7 * i as u64, mtime: 9 };
        let (back, stamp2) = cache::decode(&cache::encode(&data, stamp)).unwrap();
        assert_eq!(back, data, "shape {i}");
        assert_eq!(stamp2, stamp);
    }
}

#[test]
fn cached_load_equals_the_libsvm_parse_it_replaces() {
    let data = corpus(60, 800, 10.0, 11);
    let src = temp("roundtrip.svm");
    libsvm::write_file(&src, &data).unwrap();
    let parsed = libsvm::read_file(src.to_str().unwrap(), None).unwrap();

    let cache_path = cache::default_path(&src);
    cache::write_file(&cache_path, &parsed, cache::stamp_of(&src).unwrap()).unwrap();
    let hit = cache::load_fresh(&cache_path, &src).unwrap().expect("fresh cache must hit");
    assert_eq!(hit, parsed, "cache load must equal the parse it replaces");

    // Touching the source (longer content) turns the hit into a miss.
    std::fs::write(&src, b"1 1:1 2:2 3:3 4:4 5:5 6:6 7:7 8:8 9:9\n").unwrap();
    assert!(cache::load_fresh(&cache_path, &src).unwrap().is_none());

    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_file(&cache_path);
}

#[test]
fn corrupt_cache_file_is_an_error_not_a_silent_reparse() {
    let data = corpus(20, 300, 6.0, 3);
    let src = temp("corrupt.svm");
    libsvm::write_file(&src, &data).unwrap();
    let cache_path = cache::default_path(&src);
    cache::write_file(&cache_path, &data, cache::stamp_of(&src).unwrap()).unwrap();

    // Flip a reserved header byte: the file still "exists and is fresh",
    // so the corruption must surface as Err, not Ok(None).
    let mut bytes = std::fs::read(&cache_path).unwrap();
    bytes[6] = 1;
    std::fs::write(&cache_path, &bytes).unwrap();
    match cache::load_fresh(&cache_path, &src) {
        Err(cache::CacheError::Malformed(_)) => {}
        other => panic!("expected Malformed, got {other:?}"),
    }

    let _ = std::fs::remove_file(&src);
    let _ = std::fs::remove_file(&cache_path);
}

// ------------------------------------------------- compact model (LZMC)

#[test]
fn compact_round_trips_random_models_bitwise() {
    for seed in 0..10u64 {
        let m = random_model(5_000, 0.01, seed);
        let bytes = compact::encode(&m).unwrap();
        assert_eq!(bytes.len() as u64, compact::encoded_len(&m), "seed {seed}");
        let m2 = compact::decode(&bytes).unwrap();
        assert_eq!(m2.dim(), m.dim());
        assert_eq!(m2.penalty, m.penalty);
        assert_eq!(m2.bias.to_bits(), m.bias.to_bits());
        for (a, b) in m.weights.iter().zip(&m2.weights) {
            assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
        }
        // The opt-in f32 artifact quantizes each weight to the nearest
        // f32 and nothing else.
        let q = compact::decode(&compact::encode_f32(&m).unwrap()).unwrap();
        for (a, b) in m.weights.iter().zip(&q.weights) {
            assert_eq!(*b, f64::from(*a as f32), "seed {seed}");
        }
    }
}

#[test]
fn compact_and_text_artifacts_load_the_same_model() {
    let m = random_model(2_000, 0.02, 42);
    let text_path = temp("same.model");
    let compact_path = temp("same.lzmc");
    model_io::save(&text_path, &m).unwrap();
    compact::save(&compact_path, &m).unwrap();
    // One loader, two formats: `load` sniffs the magic.
    let from_text = model_io::load(&text_path).unwrap();
    let from_compact = model_io::load(&compact_path).unwrap();
    assert_eq!(from_compact, m, "compact round trip is exact");
    assert_eq!(from_text.dim(), from_compact.dim());
    assert_eq!(from_text.penalty, from_compact.penalty);
    // Text float printing is shortest-round-trip, so the text path is
    // exact too — the two loads must agree bitwise.
    for (a, b) in from_text.weights.iter().zip(&from_compact.weights) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_file(&text_path);
    let _ = std::fs::remove_file(&compact_path);
}

#[test]
fn sparse_scoring_of_a_compact_round_trip_is_bitwise_dense() {
    let dim = 3 * 4096 + 123;
    let m = random_model(dim, 0.03, 7);
    let loaded = compact::decode(&compact::encode(&m).unwrap()).unwrap();
    let sparse = SparseModel::from_model(&loaded, 1);
    let dense = predict::build(m.clone(), 1, 1);
    let mut rng = Rng::new(13);
    for _ in 0..50 {
        let nnz = rng.index(200);
        let idx = rng.sample_distinct(dim, nnz);
        let (indices, values): (Vec<u32>, Vec<f32>) =
            idx.into_iter().map(|j| (j as u32, rng.normal() as f32)).unzip();
        let row = RowView { indices: &indices, values: &values };
        assert_eq!(sparse.score(row).to_bits(), dense.score(row).to_bits());
    }
}

#[test]
fn compact_artifact_is_small_for_l1_sparse_models() {
    // Medline-shaped support: ~1% of 50k weights survive ℓ1.
    let m = random_model(50_000, 0.01, 5);
    let nnz = m.sparsity().nnz as u64;
    assert!(nnz > 100, "degenerate support ({nnz}) would make the ratio meaningless");
    let compact_bytes = compact::encode(&m).unwrap().len() as u64;
    let dense_dump = 8 * m.dim() as u64; // f64 per weight, zeros included
    assert!(
        compact_bytes * 4 <= dense_dump,
        "compact artifact must be <= 25% of the dense dump: {compact_bytes} vs {dense_dump}"
    );
    // And it beats the text artifact it replaces outright.
    let mut text = Vec::new();
    model_io::write(&mut text, &m).unwrap();
    assert!(
        compact_bytes < text.len() as u64,
        "compact ({compact_bytes}) must undercut text ({})",
        text.len()
    );
}

// -------------------------------------------------- text-format regression

#[test]
fn v1_and_v2_text_files_still_load_with_correct_provenance() {
    let v1 = "lazyreg-model v1\nloss logistic\ndim 6\nbias 0.25\n2:1.5\n5:-0.5\n";
    let m1 = model_io::read(v1.as_bytes()).unwrap();
    assert_eq!(m1.dim(), 6);
    assert_eq!(m1.penalty, None);
    assert_eq!(m1.bias, 0.25);
    assert_eq!(m1.weights[2], 1.5);
    assert_eq!(m1.weights[5], -0.5);

    let v2 = "lazyreg-model v2\nloss hinge\npenalty tg:0.01:10:1.5\ndim 4\nbias -1\n0:2\n";
    let m2 = model_io::read(v2.as_bytes()).unwrap();
    assert_eq!(m2.loss, Loss::Hinge);
    assert_eq!(m2.penalty.as_deref(), Some("tg:0.01:10:1.5"));
    assert_eq!(m2.weights[0], 2.0);

    // Legacy files re-save through the current writer and reload equal.
    let path = temp("regression.model");
    model_io::save(&path, &m2).unwrap();
    assert_eq!(model_io::load(&path).unwrap(), m2);
    let _ = std::fs::remove_file(&path);
}
