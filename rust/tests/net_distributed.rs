//! Multi-process localhost smoke for the `net/` subsystem, through the
//! real CLI binary:
//!
//! * a coordinator process plus two worker processes train over TCP
//!   with `--merge sparse` on a small Medline-shaped corpus, and the
//!   saved model matches a single-process `--workers 2 --merge sparse`
//!   run within 1e-10 (checked by `info --compare --tol`, the same
//!   scriptable gate CI uses);
//! * a `shard` child process serves one remote scoring shard, and a
//!   front end configured with `--remote-shards` returns the same
//!   predictions as a plain in-process server — while refusing `reload`.
//!
//! Every training process is launched with identical data/config flags:
//! the dataset never crosses the wire, each process regenerates it.

// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

use lazyreg::serve::{Client, ServeOptions, Server};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::train::{train_lazy, TrainOptions};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_lazyreg")
}

/// Kill-on-drop child guard: a failed assertion must not leak training
/// or shard processes into the test harness (or CI runner).
struct Guard(Child);

impl Drop for Guard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn wait_success(child: &mut Child, limit: Duration, who: &str) {
    let t0 = Instant::now();
    loop {
        match child.try_wait().expect("try_wait") {
            Some(status) => {
                assert!(status.success(), "{who} exited with {status}");
                return;
            }
            None => {
                assert!(t0.elapsed() < limit, "{who} still running after {limit:?}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Read the child's stdout until a line contains `marker`; return the
/// whitespace-delimited token right after it (how both the cluster
/// coordinator and the shard server publish their ephemeral port).
fn scrape_token(child: &mut Child, marker: &str) -> String {
    let stdout = child.stdout.take().expect("child stdout piped");
    let reader = BufReader::new(stdout);
    for line in reader.lines() {
        let line = line.expect("child stdout read");
        if let Some(pos) = line.find(marker) {
            let token = line[pos + marker.len()..]
                .split_whitespace()
                .next()
                .unwrap_or_else(|| panic!("no token after {marker:?} in {line:?}"))
                .to_string();
            // Keep draining in the background so the child can never
            // block on a full stdout pipe.
            std::thread::spawn(move || for _ in reader.lines() {});
            return token;
        }
    }
    panic!("child exited without printing {marker:?}");
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lazyreg_net_dist_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

/// The shared training configuration — identical for the single-process
/// reference and for every cluster process, so they all regenerate the
/// same corpus and make the same split. n=600 with the default 10% test
/// split leaves 540 training examples, divisible by 2 workers (the
/// equal-shard case the wire protocol requires).
fn train_args() -> Vec<&'static str> {
    vec![
        "--n", "600", "--d", "5000", "--epochs", "2", "--workers", "2", "--merge", "sparse",
        "--sync-interval", "50", "--seed", "13", "--reg", "enet:1e-4:1e-4",
    ]
}

#[test]
fn multi_process_cluster_training_matches_single_process() {
    let ref_model = scratch("ref.model");
    let net_model = scratch("net.model");

    // Single-process reference: the in-process sparse-merge engine.
    let status = Command::new(bin())
        .arg("train")
        .args(train_args())
        .arg("--save")
        .arg(&ref_model)
        .status()
        .expect("run single-process reference");
    assert!(status.success(), "reference train exited with {status}");

    // Coordinator on an ephemeral port; scrape the bound address.
    let coord = Command::new(bin())
        .arg("train")
        .args(train_args())
        .args(["--net", "coordinator:127.0.0.1:0", "--net-workers", "2"])
        .arg("--save")
        .arg(&net_model)
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn coordinator");
    let mut coord = Guard(coord);
    let addr = scrape_token(&mut coord.0, "workers on ");

    // Two worker processes join the round protocol.
    let mut workers: Vec<Guard> = (0..2)
        .map(|w| {
            let child = Command::new(bin())
                .arg("train")
                .args(train_args())
                .args(["--net", &format!("worker:{addr}")])
                .spawn()
                .unwrap_or_else(|e| panic!("spawn worker {w}: {e}"));
            Guard(child)
        })
        .collect();

    let limit = Duration::from_secs(120);
    for (w, g) in workers.iter_mut().enumerate() {
        wait_success(&mut g.0, limit, &format!("worker {w}"));
    }
    wait_success(&mut coord.0, limit, "coordinator");

    // The scriptable equality gate: exit 0 iff the two saved models
    // agree within 1e-10 (weights and bias).
    let compare: ExitStatus = Command::new(bin())
        .arg("info")
        .arg("--model")
        .arg(&ref_model)
        .arg("--compare")
        .arg(&net_model)
        .args(["--tol", "1e-10"])
        .status()
        .expect("run info --compare");
    assert!(compare.success(), "cluster-trained model differs from single-process model");
}

#[test]
fn serve_with_remote_shard_process_matches_in_process_scores() {
    // A quick real model, saved for the shard child process.
    let data = generate(&BowSpec::tiny(), 7);
    let report =
        train_lazy(&data, &TrainOptions { epochs: 1, ..Default::default() }).expect("train");
    let model_path = scratch("serve.model");
    lazyreg::model::io::save(&model_path, &report.model).expect("save model");

    // One remote shard in a child process, on an ephemeral port.
    let shard = Command::new(bin())
        .arg("shard")
        .arg("--model")
        .arg(&model_path)
        .args(["--shard", "0", "--shards", "1", "--addr", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn shard");
    let mut shard = Guard(shard);
    let addr = scrape_token(&mut shard.0, "serving on ");

    // Front end A scores through the child process; front end B holds
    // the weights in-process.
    let remote_opts = ServeOptions { remote_shards: vec![addr], ..Default::default() };
    let remote_srv =
        Server::spawn_with(report.model.clone(), "127.0.0.1:0", remote_opts).expect("remote serve");
    let plain_srv = Server::spawn(report.model.clone(), "127.0.0.1:0").expect("plain serve");

    let mut rc = Client::connect(remote_srv.addr()).expect("connect remote");
    let mut pc = Client::connect(plain_srv.addr()).expect("connect plain");
    let examples: Vec<Vec<(u32, f32)>> =
        vec![vec![(3, 1.0)], vec![(40, 2.0), (1_999, -1.0)], vec![]];
    for ex in &examples {
        let remote = rc.predict(ex).expect("remote predict");
        let plain = pc.predict(ex).expect("plain predict");
        assert_eq!(remote, plain, "{ex:?}");
    }

    // Hot reload is refused while remote shards are configured: the
    // weights live in the shard process, which this server cannot swap.
    let err = rc.reload(model_path.to_str().expect("utf8 path")).expect_err("reload must refuse");
    assert!(err.to_string().contains("reload-remote-shards"), "{err:#}");

    rc.quit().expect("quit");
    remote_srv.shutdown();
    plain_srv.shutdown();
    drop(shard);
}
