//! Integration tests for the pool-based data-parallel training runtime
//! on the Medline-shaped `medline_small` corpus — the
//! lazy/dense/parallel equivalence triangle, plus the pool-vs-reference
//! pin:
//!
//! * `workers = 1` must be **bit-identical** to the serial lazy trainer
//!   (same code path by construction — asserted here).
//! * Synchronous pool training must be **bit-identical** to the frozen
//!   PR 1 round-spawn engine (`testing::reference`) at `workers ∈
//!   {2, 4}` — the acceptance bar for replacing the old runtime.
//! * For `workers ∈ {2, 4}`, the engine running **lazy** workers must
//!   match the engine running **dense** workers far past the paper's
//!   criterion (3 significant figures asserted per weight; the absolute
//!   diff bound is orders of magnitude tighter): the per-worker update
//!   maps are the paper's exact lazy ≡ dense equivalence and the shard +
//!   merge schedule is identical.
//! * Parallel averaging vs *serial* dense training is a statistical,
//!   not numerical, equivalence (averaged shard trajectories move
//!   ~1/workers as far per example), so against serial dense we assert
//!   objective closeness with an honest loose bound, not sig-figs.


// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use lazyreg::data::SparseDataset;
use lazyreg::model::LinearModel;
use lazyreg::prelude::*;
use lazyreg::synth::{generate, BowSpec};
use lazyreg::testing::agrees_to_sig_figs;
use lazyreg::testing::reference::round_spawn_train_lazy_xy;
use lazyreg::train::{train_parallel, train_parallel_dense_xy};

fn medline_small() -> SparseDataset {
    generate(
        &BowSpec { n_examples: 1_500, n_features: 8_000, avg_nnz: 50.0, ..Default::default() },
        1234,
    )
}

fn opts(workers: usize) -> TrainOptions {
    TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-5, 1e-4),
        schedule: Schedule::InvSqrtT { eta0: 0.3 },
        epochs: 4,
        shuffle: false,
        workers,
        sync_interval: Some(32),
        ..Default::default()
    }
}

/// Mean regularized objective of `model` over the corpus:
/// (1/n) Σ loss + λ₁‖w‖₁ + (λ₂/2)‖w‖₂².
fn objective(model: &LinearModel, data: &SparseDataset, reg: &Regularizer) -> f64 {
    let n = data.n_examples();
    let mut sum = 0.0f64;
    for r in 0..n {
        sum += model.example_loss(data.x().row(r), f64::from(data.labels()[r]));
    }
    sum / n as f64 + reg.penalty(&model.weights)
}

#[test]
fn one_worker_is_bit_identical_to_serial_lazy() {
    let data = medline_small();
    let mut o = opts(1);
    o.epochs = 3;
    o.shuffle = true;
    let serial = train_lazy(&data, &o).unwrap();
    let par = train_parallel(&data, &o).unwrap();
    assert_eq!(serial.model.weights, par.model.weights, "weights diverged");
    assert_eq!(serial.model.bias, par.model.bias, "bias diverged");
    assert_eq!(serial.rebases, par.rebases);
    for (a, b) in serial.epochs.iter().zip(par.epochs.iter()) {
        assert_eq!(a.mean_loss, b.mean_loss, "epoch {} loss diverged", a.epoch);
    }
}

#[test]
fn pool_sync_is_bitwise_identical_to_round_spawn_engine() {
    // The acceptance pin for the runtime refactor: the persistent pool
    // in synchronous flat-merge mode must reproduce the PR 1 round-spawn
    // engine bit for bit — same shard slices, same per-round merge
    // arithmetic, same broadcast — at production-representative scale.
    let data = medline_small();
    for workers in [2usize, 4] {
        let o = opts(workers);
        let pool = train_parallel(&data, &o).unwrap();
        let reference = round_spawn_train_lazy_xy(data.x(), data.labels(), &o).unwrap();
        assert_eq!(
            pool.model.weights, reference.model.weights,
            "workers={workers}: pool diverged from the round-spawn reference"
        );
        assert_eq!(pool.model.bias, reference.model.bias);
        assert_eq!(pool.rebases, reference.rebases);
        assert_eq!(pool.examples, reference.examples);
        for (a, b) in pool.epochs.iter().zip(reference.epochs.iter()) {
            assert_eq!(a.mean_loss, b.mean_loss, "epoch {} loss diverged", a.epoch);
            assert_eq!(a.objective, b.objective, "epoch {} objective diverged", a.epoch);
        }
    }
    // Epoch-synchronous cadence too (one merge per epoch).
    let mut o = opts(4);
    o.sync_interval = None;
    let pool = train_parallel(&data, &o).unwrap();
    let reference = round_spawn_train_lazy_xy(data.x(), data.labels(), &o).unwrap();
    assert_eq!(pool.model.weights, reference.model.weights);
    assert_eq!(pool.model.bias, reference.model.bias);
}

#[test]
fn tree_merge_tracks_flat_merge_within_float_tolerance() {
    let data = medline_small();
    let flat = opts(4);
    let mut tree = flat;
    tree.merge = MergeMode::Tree;
    let a = train_parallel(&data, &flat).unwrap();
    let b = train_parallel(&data, &tree).unwrap();
    // Same weighted mean per merge, different fold order: agreement to
    // float tolerance through a full multi-epoch training run.
    let diff = a.model.max_weight_diff(&b.model);
    assert!(diff < 1e-6, "tree vs flat merge diverged: {diff}");
    assert!(b.final_loss() < b.epochs[0].mean_loss, "tree-merge run did not learn");
    // And the tree merge is itself deterministic.
    let b2 = train_parallel(&data, &tree).unwrap();
    assert_eq!(b.model.weights, b2.model.weights);
}

#[test]
fn pipelined_sync_is_deterministic_and_learns() {
    let data = medline_small();
    let mut o = opts(4);
    o.pipeline_sync = true;
    let a = train_parallel(&data, &o).unwrap();
    let b = train_parallel(&data, &o).unwrap();
    // One-round-stale broadcast is a *defined* estimator: repeated runs
    // are bitwise identical regardless of thread timing.
    assert_eq!(a.model.weights, b.model.weights);
    assert_eq!(a.model.bias, b.model.bias);
    for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
        assert_eq!(ea.mean_loss, eb.mean_loss);
    }
    // And it still learns the medline-shaped signal.
    assert!(
        a.final_loss() < a.epochs[0].mean_loss,
        "pipelined run did not learn: {} -> {}",
        a.epochs[0].mean_loss,
        a.final_loss()
    );
    assert!(a.final_loss().is_finite());
    assert_eq!(a.examples, (data.n_examples() * 4) as u64);
}

#[test]
fn epoch_stats_report_objective_and_merge_time() {
    let data = medline_small();
    let par = train_parallel(&data, &opts(4)).unwrap();
    for e in &par.epochs {
        assert!(e.objective.is_finite());
        // Elastic net: R(w) >= 0, so the objective dominates the loss.
        assert!(e.objective >= e.mean_loss);
        assert!(e.merge_seconds >= 0.0 && e.merge_seconds <= e.seconds);
    }
    // Serial driver: objective populated, merge time identically zero.
    let serial = train_lazy(&data, &opts(1)).unwrap();
    for e in &serial.epochs {
        assert!(e.objective.is_finite() && e.objective >= e.mean_loss);
        assert_eq!(e.merge_seconds, 0.0);
    }
}

#[test]
fn sharded_lazy_matches_sharded_dense_to_3_sig_figs() {
    let data = medline_small();
    for workers in [2usize, 4] {
        let o = opts(workers);
        let lazy = train_parallel(&data, &o).unwrap();
        let dense = train_parallel_dense_xy(data.x(), data.labels(), &o).unwrap();

        // Identical shard/merge schedule + the paper's per-update
        // equivalence: the engines agree to float rounding.
        let diff = lazy.model.max_weight_diff(&dense.model);
        assert!(diff < 1e-8, "workers={workers}: lazy vs dense diff {diff}");
        for (a, b) in lazy.model.weights.iter().zip(dense.model.weights.iter()) {
            // Sig-fig (relative) comparison is meaningless for weights
            // at the float-cancellation floor; those are covered by the
            // absolute bound above.
            if a.abs().max(b.abs()) < 1e-10 {
                continue;
            }
            assert!(
                agrees_to_sig_figs(*a, *b, 3),
                "workers={workers}: weight {a} vs {b}"
            );
            // The paper's §7 criterion holds too, with room to spare.
            assert!(agrees_to_sig_figs(*a, *b, 4), "4 sig figs: {a} vs {b}");
        }
        // Loss curves agree as well (pre-update losses over the same
        // visit order).
        for (a, b) in lazy.epochs.iter().zip(dense.epochs.iter()) {
            assert!(
                agrees_to_sig_figs(a.mean_loss, b.mean_loss, 3),
                "workers={workers} epoch {}: {} vs {}",
                a.epoch,
                a.mean_loss,
                b.mean_loss
            );
        }
    }
}

#[test]
fn sharded_workers_track_serial_dense_on_the_objective() {
    // Model averaging is a different estimator from serial SGD, so this
    // is a statistical-closeness bound, not a numerical one: both land
    // near the same regularized optimum, with the parallel run lagging
    // by roughly one factor-of-workers in effective steps.
    let data = medline_small();
    let base = opts(1);
    let dense = train_dense(&data, &base).unwrap();
    let obj_dense = objective(&dense.model, &data, &base.reg);

    for workers in [2usize, 4] {
        let par = train_parallel(&data, &opts(workers)).unwrap();
        let obj_par = objective(&par.model, &data, &base.reg);
        let rel = (obj_par - obj_dense).abs() / obj_dense.abs();
        assert!(
            rel < 0.5,
            "workers={workers}: objective {obj_par} vs dense {obj_dense} (rel {rel:.3})"
        );
        // And it genuinely learns: final online loss well below the
        // first epoch's.
        assert!(par.final_loss() < par.epochs[0].mean_loss);
    }
}

#[test]
fn epoch_synchronous_default_also_converges() {
    let data = medline_small();
    let mut o = opts(4);
    o.sync_interval = None; // one merge per epoch
    let par = train_parallel(&data, &o).unwrap();
    assert!(par.final_loss() < par.epochs[0].mean_loss);
    assert!(par.final_loss().is_finite());
}

#[test]
fn hogwild_tracks_flat_merge_on_the_objective_across_seeds() {
    // `merge = none` is the lock-free HOGWILD pool: one shared weight
    // vector, racing sparse updates, no merge. It is non-deterministic
    // by design, so the acceptance bar is *statistical* and one-sided:
    // averaging dampens the effective per-example step (~1/workers)
    // while lock-free updates land at full strength, so hogwild
    // routinely ends at or below the flat objective — what this guards
    // against is ending much worse (diverging races).
    let data = medline_small();
    let mut worse = 0usize;
    for seed in [7u64, 19, 23] {
        let mut flat = opts(4);
        flat.shuffle = true;
        flat.seed = seed;
        let mut hog = flat;
        hog.merge = MergeMode::None;
        let f = train_parallel(&data, &flat).unwrap();
        let h = train_parallel(&data, &hog).unwrap();
        let of = objective(&f.model, &data, &flat.reg);
        let oh = objective(&h.model, &data, &flat.reg);
        assert!(oh.is_finite(), "seed {seed}: hogwild objective not finite");
        let tol = 0.15 * of.abs().max(0.05);
        assert!(
            oh <= of + tol,
            "seed {seed}: hogwild objective {oh} much worse than flat {of} (tol {tol})"
        );
        if oh > of {
            worse += 1;
        }
        // It learns the signal outright, not just relative to flat.
        assert!(h.final_loss() < h.epochs[0].mean_loss, "seed {seed}: did not learn");
        // No merge ⇒ the sparse-merge touched-fraction stat stays zero.
        for e in &h.epochs {
            assert_eq!(e.touched_frac, 0.0);
        }
    }
    assert!(worse < 3, "hogwild ended worse than flat on every seed");
}

#[test]
fn hogwild_rejects_pipelining_and_falls_back_off_the_lazy_path() {
    let data = medline_small();
    // none + pipeline_sync is rejected up front: there is no merge to
    // overlap with the next round.
    let mut o = opts(4);
    o.merge = MergeMode::None;
    o.pipeline_sync = true;
    let err = o.validate().unwrap_err().to_string();
    assert!(err.contains("pipeline"), "unexpected error: {err}");
    assert!(train_parallel(&data, &o).is_err());
    // Dense workers have no lazy trainer to share; the driver falls
    // back to the flat merge and still trains.
    let mut d = opts(2);
    d.merge = MergeMode::None;
    let report = train_parallel_dense_xy(data.x(), data.labels(), &d).unwrap();
    assert!(report.final_loss().is_finite());
    assert!(report.final_loss() < report.epochs[0].mean_loss);
}

#[test]
fn parallel_report_accounts_all_examples_and_epochs() {
    let data = medline_small();
    let mut o = opts(4);
    o.epochs = 2;
    let report = train_parallel(&data, &o).unwrap();
    assert_eq!(report.examples, (data.n_examples() * 2) as u64);
    assert_eq!(report.epochs.len(), 2);
    for e in &report.epochs {
        assert_eq!(e.examples, data.n_examples());
        assert!(e.mean_loss.is_finite());
    }
    assert!(report.throughput > 0.0);
}
