//! Integration tests for the data-parallel sharded training engine on
//! the Medline-shaped `medline_small` corpus — the lazy/dense/parallel
//! equivalence triangle:
//!
//! * `workers = 1` must be **bit-identical** to the serial lazy trainer
//!   (same code path by construction — asserted here).
//! * For `workers ∈ {2, 4}`, the engine running **lazy** workers must
//!   match the engine running **dense** workers far past the paper's
//!   criterion (3 significant figures asserted per weight; the absolute
//!   diff bound is orders of magnitude tighter): the per-worker update
//!   maps are the paper's exact lazy ≡ dense equivalence and the shard +
//!   merge schedule is identical.
//! * Parallel averaging vs *serial* dense training is a statistical,
//!   not numerical, equivalence (averaged shard trajectories move
//!   ~1/workers as far per example), so against serial dense we assert
//!   objective closeness with an honest loose bound, not sig-figs.

use lazyreg::data::SparseDataset;
use lazyreg::model::LinearModel;
use lazyreg::prelude::*;
use lazyreg::synth::{generate, BowSpec};
use lazyreg::testing::agrees_to_sig_figs;
use lazyreg::train::{train_parallel, train_parallel_dense_xy};

fn medline_small() -> SparseDataset {
    generate(
        &BowSpec { n_examples: 1_500, n_features: 8_000, avg_nnz: 50.0, ..Default::default() },
        1234,
    )
}

fn opts(workers: usize) -> TrainOptions {
    TrainOptions {
        algo: Algo::Fobos,
        reg: Regularizer::elastic_net(1e-5, 1e-4),
        schedule: Schedule::InvSqrtT { eta0: 0.3 },
        epochs: 4,
        shuffle: false,
        workers,
        sync_interval: Some(32),
        ..Default::default()
    }
}

/// Mean regularized objective of `model` over the corpus:
/// (1/n) Σ loss + λ₁‖w‖₁ + (λ₂/2)‖w‖₂².
fn objective(model: &LinearModel, data: &SparseDataset, reg: &Regularizer) -> f64 {
    let n = data.n_examples();
    let mut sum = 0.0f64;
    for r in 0..n {
        sum += model.example_loss(data.x().row(r), f64::from(data.labels()[r]));
    }
    sum / n as f64 + reg.penalty(&model.weights)
}

#[test]
fn one_worker_is_bit_identical_to_serial_lazy() {
    let data = medline_small();
    let mut o = opts(1);
    o.epochs = 3;
    o.shuffle = true;
    let serial = train_lazy(&data, &o).unwrap();
    let par = train_parallel(&data, &o).unwrap();
    assert_eq!(serial.model.weights, par.model.weights, "weights diverged");
    assert_eq!(serial.model.bias, par.model.bias, "bias diverged");
    assert_eq!(serial.rebases, par.rebases);
    for (a, b) in serial.epochs.iter().zip(par.epochs.iter()) {
        assert_eq!(a.mean_loss, b.mean_loss, "epoch {} loss diverged", a.epoch);
    }
}

#[test]
fn sharded_lazy_matches_sharded_dense_to_3_sig_figs() {
    let data = medline_small();
    for workers in [2usize, 4] {
        let o = opts(workers);
        let lazy = train_parallel(&data, &o).unwrap();
        let dense = train_parallel_dense_xy(data.x(), data.labels(), &o).unwrap();

        // Identical shard/merge schedule + the paper's per-update
        // equivalence: the engines agree to float rounding.
        let diff = lazy.model.max_weight_diff(&dense.model);
        assert!(diff < 1e-8, "workers={workers}: lazy vs dense diff {diff}");
        for (a, b) in lazy.model.weights.iter().zip(dense.model.weights.iter()) {
            // Sig-fig (relative) comparison is meaningless for weights
            // at the float-cancellation floor; those are covered by the
            // absolute bound above.
            if a.abs().max(b.abs()) < 1e-10 {
                continue;
            }
            assert!(
                agrees_to_sig_figs(*a, *b, 3),
                "workers={workers}: weight {a} vs {b}"
            );
            // The paper's §7 criterion holds too, with room to spare.
            assert!(agrees_to_sig_figs(*a, *b, 4), "4 sig figs: {a} vs {b}");
        }
        // Loss curves agree as well (pre-update losses over the same
        // visit order).
        for (a, b) in lazy.epochs.iter().zip(dense.epochs.iter()) {
            assert!(
                agrees_to_sig_figs(a.mean_loss, b.mean_loss, 3),
                "workers={workers} epoch {}: {} vs {}",
                a.epoch,
                a.mean_loss,
                b.mean_loss
            );
        }
    }
}

#[test]
fn sharded_workers_track_serial_dense_on_the_objective() {
    // Model averaging is a different estimator from serial SGD, so this
    // is a statistical-closeness bound, not a numerical one: both land
    // near the same regularized optimum, with the parallel run lagging
    // by roughly one factor-of-workers in effective steps.
    let data = medline_small();
    let base = opts(1);
    let dense = train_dense(&data, &base).unwrap();
    let obj_dense = objective(&dense.model, &data, &base.reg);

    for workers in [2usize, 4] {
        let par = train_parallel(&data, &opts(workers)).unwrap();
        let obj_par = objective(&par.model, &data, &base.reg);
        let rel = (obj_par - obj_dense).abs() / obj_dense.abs();
        assert!(
            rel < 0.5,
            "workers={workers}: objective {obj_par} vs dense {obj_dense} (rel {rel:.3})"
        );
        // And it genuinely learns: final online loss well below the
        // first epoch's.
        assert!(par.final_loss() < par.epochs[0].mean_loss);
    }
}

#[test]
fn epoch_synchronous_default_also_converges() {
    let data = medline_small();
    let mut o = opts(4);
    o.sync_interval = None; // one merge per epoch
    let par = train_parallel(&data, &o).unwrap();
    assert!(par.final_loss() < par.epochs[0].mean_loss);
    assert!(par.final_loss().is_finite());
}

#[test]
fn parallel_report_accounts_all_examples_and_epochs() {
    let data = medline_small();
    let mut o = opts(4);
    o.epochs = 2;
    let report = train_parallel(&data, &o).unwrap();
    assert_eq!(report.examples, (data.n_examples() * 2) as u64);
    assert_eq!(report.epochs.len(), 2);
    for e in &report.epochs {
        assert_eq!(e.examples, data.n_examples());
        assert!(e.mean_loss.is_finite());
    }
    assert!(report.throughput > 0.0);
}
