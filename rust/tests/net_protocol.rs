//! The `net/` subsystem against its promises, in one process:
//!
//! * frame codec round-trips every frame type over randomized payloads,
//!   and rejects truncated / oversized / wrong-magic / wrong-version /
//!   malformed bytes with a structured error (never a panic, and — all
//!   decoding here runs over in-memory slices — never a hang);
//! * remote shard scoring is bitwise-identical to the in-process
//!   predictor at shard counts {1, 2, 7} (7 > the block count, so some
//!   shards own no blocks at all);
//! * a stale shard (model-version mismatch) is refused, not mixed in —
//!   at connect time and after a rolling restart mid-stream;
//! * a shard connection survives its server restarting, and a replica
//!   group survives one replica dying, bitwise-identically;
//! * a slow-loris peer (partial header or payload, then silence) trips
//!   the read deadline as a structured [`FrameError::Timeout`];
//! * socket-coordinated sparse-merge training matches the in-process
//!   `--merge sparse` engine within 1e-10.

// The library is sync-facade-only under `--cfg loom`; this suite
// needs the full crate.
#![cfg(not(loom))]

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use lazyreg::data::RowView;
use lazyreg::loss::Loss;
use lazyreg::model::LinearModel;
use lazyreg::net::frame::{read_frame, write_frame, Frame, FrameError, MAX_PAYLOAD};
use lazyreg::net::{
    run_worker, Channel, ClusterCoordinator, Deadlines, RemoteShardModel, ShardServer,
    ShardUnavailable,
};
use lazyreg::optim::Regularizer;
use lazyreg::predict::{self, Predictor};
use lazyreg::synth::{generate, BowSpec};
use lazyreg::train::{train_parallel, MergeMode, TrainOptions};
use lazyreg::util::Rng;

// ---------------------------------------------------------------- codec

fn sorted_indices(rng: &mut Rng, max_len: usize, dim: u32) -> Vec<u32> {
    let len = rng.index(max_len + 1);
    let mut v: Vec<u32> = (0..len).map(|_| rng.below(dim as u64) as u32).collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn values_for(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// One randomized instance of every frame type.
fn random_frames(rng: &mut Rng) -> Vec<Frame> {
    let dim = 1 + rng.below(100_000) as u32;

    let push_idx = sorted_indices(rng, 40, dim);
    let push_vals = values_for(rng, push_idx.len());
    let merged_idx = sorted_indices(rng, 40, dim);
    let merged_vals = values_for(rng, merged_idx.len());
    let model_idx = sorted_indices(rng, 40, dim);
    let model_vals = values_for(rng, model_idx.len());
    let resume_idx = sorted_indices(rng, 40, dim);
    let resume_vals = values_for(rng, resume_idx.len());

    // A small CSR slice: sorted indices within each row.
    let n_rows = rng.index(5);
    let mut indptr = vec![0u32];
    let mut csr_idx = Vec::new();
    let mut csr_vals = Vec::new();
    for _ in 0..n_rows {
        let row = sorted_indices(rng, 12, dim);
        for &j in &row {
            csr_idx.push(j);
            csr_vals.push(rng.f32());
        }
        indptr.push(csr_idx.len() as u32);
    }

    let partial_rows: Vec<Vec<(u32, f64)>> = (0..rng.index(4))
        .map(|_| (0..rng.index(6)).map(|_| (rng.below(64) as u32, rng.normal())).collect())
        .collect();

    vec![
        Frame::Hello {
            role: 1 + rng.below(4) as u8,
            shard: rng.below(8) as u32,
            shards: 1 + rng.below(8) as u32,
            dim: dim as u64,
            examples: rng.below(1 << 20),
            version: rng.below(10),
            penalty: "enet:1e-5:1e-5".to_string(),
        },
        Frame::Bye,
        Frame::Abort { reason: "synthetic refusal".to_string() },
        Frame::SyncPush {
            round: rng.below(1 << 30),
            examples: rng.below(1 << 16),
            loss: rng.normal(),
            bias: rng.normal(),
            indices: push_idx,
            values: push_vals,
        },
        Frame::SyncUnion {
            round: rng.below(1 << 30),
            next_steps: rng.below(1 << 16),
            indices: sorted_indices(rng, 40, dim),
        },
        Frame::SyncVals {
            round: rng.below(1 << 30),
            pressure: rng.bool(0.5),
            objective: if rng.bool(0.5) { Some(rng.normal()) } else { None },
            values: values_for(rng, rng.index(40)),
        },
        Frame::SyncMerged {
            round: rng.below(1 << 30),
            flush: rng.bool(0.5),
            want_objective: rng.bool(0.5),
            bias: rng.normal(),
            indices: merged_idx,
            values: merged_vals,
        },
        Frame::ScoreReq { seq: rng.below(1 << 40), indptr, indices: csr_idx, values: csr_vals },
        Frame::ScorePartial {
            seq: rng.below(1 << 40),
            version: rng.below(10),
            rows: partial_rows,
        },
        Frame::ModelReq,
        Frame::Model {
            dim: dim as u64,
            bias: rng.normal(),
            rebases: rng.below(100),
            penalty: "tg:0.01:10:1.5".to_string(),
            indices: model_idx,
            values: model_vals,
        },
        Frame::Ping { nonce: rng.next_u64() },
        Frame::Pong { nonce: rng.next_u64() },
        Frame::Resume {
            round: rng.below(1 << 30),
            epoch: rng.below(1 << 10),
            offset: rng.below(1 << 20),
            steps: rng.below(1 << 30),
            rebases: rng.below(100),
            bias: rng.normal(),
            indices: resume_idx,
            values: resume_vals,
        },
    ]
}

#[test]
fn every_frame_type_round_trips_over_random_payloads() {
    let mut rng = Rng::new(0xF4A3E);
    for case in 0..50 {
        for frame in random_frames(&mut rng) {
            let mut buf = Vec::new();
            let written = write_frame(&mut buf, &frame)
                .unwrap_or_else(|e| panic!("case {case}: encode {}: {e}", frame.name()));
            assert_eq!(written, buf.len() as u64);
            let (decoded, read) = read_frame(&mut buf.as_slice())
                .unwrap_or_else(|e| panic!("case {case}: decode {}: {e}", frame.name()));
            assert_eq!(read, written);
            assert_eq!(decoded, frame, "case {case}");
        }
    }
}

#[test]
fn every_truncation_of_every_frame_type_is_a_structured_error() {
    let mut rng = Rng::new(0x7D06);
    for frame in random_frames(&mut rng) {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).expect("encode");
        for cut in 0..buf.len() {
            match read_frame(&mut &buf[..cut]) {
                Err(FrameError::Truncated) => {}
                other => panic!(
                    "{} cut at {cut}/{}: expected Truncated, got {other:?}",
                    frame.name(),
                    buf.len()
                ),
            }
        }
    }
}

#[test]
fn corrupted_headers_are_rejected_with_the_specific_error() {
    let mut buf = Vec::new();
    write_frame(&mut buf, &Frame::Bye).expect("encode");

    let mut bad_magic = buf.clone();
    bad_magic[0] = b'X';
    assert!(matches!(read_frame(&mut bad_magic.as_slice()), Err(FrameError::BadMagic(_))));

    let mut bad_version = buf.clone();
    bad_version[4] = 0xFF;
    bad_version[5] = 0xFF;
    assert!(matches!(
        read_frame(&mut bad_version.as_slice()),
        Err(FrameError::BadVersion(0xFFFF))
    ));

    let mut bad_type = buf.clone();
    bad_type[6] = 200;
    assert!(matches!(read_frame(&mut bad_type.as_slice()), Err(FrameError::UnknownType(200))));

    // A header *declaring* an oversized payload is refused before any
    // allocation or read of the payload itself.
    let mut oversized = buf.clone();
    let len = (MAX_PAYLOAD as u32) + 1;
    oversized[8..12].copy_from_slice(&len.to_le_bytes());
    assert!(matches!(
        read_frame(&mut oversized.as_slice()),
        Err(FrameError::Oversized { .. })
    ));
}

#[test]
fn structurally_invalid_payloads_are_malformed_not_panics() {
    // Unsorted sync indices: encodable (the encoder checks only length
    // pairing), but the decoder must refuse them — the trainers index
    // slots straight off these lists.
    let mut buf = Vec::new();
    let unsorted = Frame::SyncUnion { round: 0, next_steps: 1, indices: vec![5, 3] };
    write_frame(&mut buf, &unsorted).expect("encode");
    assert!(matches!(read_frame(&mut buf.as_slice()), Err(FrameError::Malformed(_))));

    // A CSR slice whose row indices are unsorted is equally refused, so
    // the shard server's binary searches stay in bounds.
    let mut buf = Vec::new();
    let bad_csr = Frame::ScoreReq {
        seq: 1,
        indptr: vec![0, 2],
        indices: vec![9, 2],
        values: vec![1.0, 1.0],
    };
    write_frame(&mut buf, &bad_csr).expect("encode");
    assert!(matches!(read_frame(&mut buf.as_slice()), Err(FrameError::Malformed(_))));
}

// ------------------------------------------------------- remote shards

/// A model wide enough for 3 score blocks (dim 10_000, block 4096), so
/// 7 shards leave some shards with no blocks at all.
fn random_model(d: usize, seed: u64) -> LinearModel {
    let mut rng = Rng::new(seed);
    let mut m = LinearModel::zeros(d, Loss::Logistic);
    for w in m.weights.iter_mut() {
        if rng.bool(0.3) {
            *w = rng.normal();
        }
    }
    m.bias = rng.normal();
    m
}

fn random_rows(d: usize, n: usize, seed: u64) -> Vec<(Vec<u32>, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = sorted_indices(&mut rng, 30, d as u32);
        let vals = idx.iter().map(|_| rng.f32()).collect();
        rows.push((idx, vals));
    }
    // Degenerate rows ride along: empty, and one touching both ends.
    rows.push((Vec::new(), Vec::new()));
    rows.push((vec![0, (d - 1) as u32], vec![1.0, -1.0]));
    rows
}

#[test]
fn remote_shard_scoring_is_bitwise_identical_to_in_process() {
    let d = 10_000;
    let model = random_model(d, 0xA11CE);
    let examples = random_rows(d, 20, 0xB0B);
    let rows: Vec<RowView<'_>> =
        examples.iter().map(|(i, v)| RowView { indices: i, values: v }).collect();

    for &shards in &[1usize, 2, 7] {
        let servers: Vec<ShardServer> = (0..shards)
            .map(|s| {
                ShardServer::spawn(&model, s, shards, "127.0.0.1:0", 1)
                    .unwrap_or_else(|e| panic!("spawn shard {s}/{shards}: {e:#}"))
            })
            .collect();
        let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();

        let remote = RemoteShardModel::connect(&model, &addrs, 1)
            .unwrap_or_else(|e| panic!("connect {shards} shards: {e:#}"));
        let local = predict::build(model.clone(), shards, 1);

        let want = local.score_batch(&rows);
        let got = remote.try_score_batch(&rows).expect("remote scoring");
        assert_eq!(got.len(), want.len());
        for (r, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                g.to_bits() == w.to_bits(),
                "shards={shards} row {r}: remote {g:?} != local {w:?}"
            );
        }
        // Empty batches are legal frames too.
        assert!(remote.try_score_batch(&[]).expect("empty batch").is_empty());

        for s in servers {
            s.shutdown();
        }
    }
}

/// Millisecond-scale deadlines so failure-path tests conclude fast.
fn short_deadlines() -> Deadlines {
    Deadlines {
        reply: Duration::from_millis(500),
        silence: Duration::from_millis(1_000),
        round: Duration::from_millis(2_000),
        write: Duration::from_millis(500),
        heartbeat: Duration::from_millis(100),
        failover: Duration::from_millis(400),
    }
}

#[test]
fn stale_shard_version_is_refused_not_mixed() {
    let d = 5_000;
    let model = random_model(d, 0x57A1E);
    // The range's only replica serves version 2; the front end expects
    // 1. The handshake quarantines it, which leaves no current replica
    // — startup must refuse loudly, naming the version skew.
    let server = ShardServer::spawn(&model, 0, 1, "127.0.0.1:0", 2).expect("spawn");
    let addrs = vec![server.addr().to_string()];
    let err = RemoteShardModel::connect_with(&model, &addrs, 1, short_deadlines())
        .err()
        .expect("stale shard must refuse at connect");
    let msg = format!("{err:#}");
    assert!(msg.contains("version"), "unexpected error: {msg}");
    server.shutdown();
}

#[test]
fn rolling_restart_version_skew_is_quarantined_not_mixed() {
    let d = 5_000;
    let model = random_model(d, 0x0DD);
    let server = ShardServer::spawn(&model, 0, 1, "127.0.0.1:0", 1).expect("spawn");
    let addr = server.addr().to_string();
    let remote =
        RemoteShardModel::connect_with(&model, &[addr.clone()], 1, short_deadlines())
            .expect("connect");

    let row = (vec![3u32, 17], vec![1.0f32, 2.0]);
    let rows = [RowView { indices: &row.0, values: &row.1 }];
    remote.try_score_batch(&rows).expect("first score");

    // Rolling restart lands a *newer* model on the same port. The
    // failover handshake sees the skew, quarantines the replica, and —
    // with no current sibling — the batch fails with the structured
    // shard-unavailable error naming the version. Never a mixed score.
    server.shutdown();
    let upgraded = ShardServer::spawn(&model, 0, 1, &addr, 2).expect("respawn v2");
    let err = remote.try_score_batch(&rows).expect_err("skewed replica must refuse");
    assert!(
        err.chain().any(|c| c.downcast_ref::<ShardUnavailable>().is_some()),
        "expected ShardUnavailable in the chain: {err:#}"
    );
    assert!(format!("{err:#}").contains("version"), "unexpected error: {err:#}");
    // The infallible trait path degrades to NaN instead of panicking
    // (the serve path uses try_* and answers `err shard-unavailable`).
    assert!(remote.score(rows[0]).is_nan());
    upgraded.shutdown();
}

#[test]
fn shard_connection_reconnects_after_server_restart() {
    let d = 5_000;
    let model = random_model(d, 0xDEAD);
    let server = ShardServer::spawn(&model, 0, 1, "127.0.0.1:0", 1).expect("spawn");
    let addr = server.addr().to_string();
    let remote = RemoteShardModel::connect(&model, &[addr.clone()], 1).expect("connect");

    let row = (vec![5u32, 4_000], vec![1.5f32, -0.5]);
    let rows = [RowView { indices: &row.0, values: &row.1 }];
    let before = remote.try_score_batch(&rows).expect("first score");

    // Kill the server, restart it on the same port (std listeners set
    // SO_REUSEADDR on unix), and score again: the failover sweep
    // reconnects to the same replica within its budget and resends the
    // stateless request — no new `connect`, bitwise-identical scores.
    server.shutdown();
    let revived = ShardServer::spawn(&model, 0, 1, &addr, 1).expect("respawn");
    let after = remote.try_score_batch(&rows).expect("score after restart");
    assert_eq!(before[0].to_bits(), after[0].to_bits());
    revived.shutdown();
}

#[test]
fn replica_failover_is_bitwise_identical() {
    let d = 5_000;
    let model = random_model(d, 0xFA11);
    let a = ShardServer::spawn(&model, 0, 1, "127.0.0.1:0", 1).expect("spawn a");
    let b = ShardServer::spawn(&model, 0, 1, "127.0.0.1:0", 1).expect("spawn b");
    let group = vec![format!("{}|{}", a.addr(), b.addr())];
    let remote = RemoteShardModel::connect_with(&model, &group, 1, short_deadlines())
        .expect("connect group");
    assert_eq!(remote.n_shards(), 1);

    let examples = random_rows(d, 8, 0xCAFE);
    let rows: Vec<RowView<'_>> =
        examples.iter().map(|(i, v)| RowView { indices: i, values: v }).collect();
    let before = remote.try_score_batch(&rows).expect("score via replica a");

    // Kill the active replica: the next batch fails over to the
    // sibling and — score requests being stateless resends against an
    // identical weight slice — produces bitwise-identical scores.
    a.shutdown();
    let after = remote.try_score_batch(&rows).expect("score via replica b");
    for (r, (x, y)) in before.iter().zip(after.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "row {r}: failover changed the score");
    }

    // With every replica down the budgeted sweep gives up with the
    // structured marker the serve layer maps to `err shard-unavailable`.
    b.shutdown();
    let err = remote.try_score_batch(&rows).expect_err("no replicas left");
    assert!(
        err.chain().any(|c| c.downcast_ref::<ShardUnavailable>().is_some()),
        "expected ShardUnavailable in the chain: {err:#}"
    );
}

// ------------------------------------------------------- slow loris

/// Spawn a listener that accepts one connection, writes `bytes`, then
/// stalls (holding the socket open) until the test ends.
fn stalling_peer(bytes: Vec<u8>) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind staller");
    let addr = listener.local_addr().expect("staller addr");
    let h = std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            s.write_all(&bytes).expect("partial write");
            let _ = s.flush();
            // Stall well past the client's deadline, then hang up.
            std::thread::sleep(Duration::from_millis(400));
        }
    });
    (addr, h)
}

#[test]
fn slow_loris_partial_header_trips_the_read_deadline() {
    let mut encoded = Vec::new();
    write_frame(&mut encoded, &Frame::Bye).expect("encode");
    // Five bytes of a twelve-byte header, then silence.
    let (addr, peer) = stalling_peer(encoded[..5].to_vec());
    let stream = TcpStream::connect(addr).expect("connect");
    let mut chan = Channel::new(stream).expect("channel");
    chan.set_read_deadline(Duration::from_millis(100)).expect("arm deadline");
    match chan.recv() {
        Err(FrameError::Timeout) => {}
        other => panic!("expected Timeout on a stalled header, got {other:?}"),
    }
    let _ = peer.join();
}

#[test]
fn slow_loris_partial_payload_trips_the_read_deadline() {
    let mut encoded = Vec::new();
    write_frame(&mut encoded, &Frame::Abort { reason: "stalling mid-payload".to_string() })
        .expect("encode");
    assert!(encoded.len() > 14, "need a payload to truncate");
    // A complete, valid header promising a payload — then only two
    // payload bytes before the stall.
    let (addr, peer) = stalling_peer(encoded[..14].to_vec());
    let stream = TcpStream::connect(addr).expect("connect");
    let mut chan = Channel::new(stream).expect("channel");
    chan.set_read_deadline(Duration::from_millis(100)).expect("arm deadline");
    match chan.recv() {
        Err(FrameError::Timeout) => {}
        other => panic!("expected Timeout on a stalled payload, got {other:?}"),
    }
    let _ = peer.join();
}

// ------------------------------------------------- distributed training

#[test]
fn tcp_cluster_matches_in_process_sparse_merge() {
    let data = generate(&BowSpec::tiny(), 97);
    let opts = TrainOptions {
        epochs: 2,
        workers: 2,
        merge: MergeMode::Sparse,
        sync_interval: Some(50),
        reg: Regularizer::elastic_net(1e-4, 1e-4),
        seed: 13,
        ..Default::default()
    };
    let reference = train_parallel(&data, &opts).expect("in-process sparse");

    let coord = ClusterCoordinator::bind("127.0.0.1:0", 2).expect("bind");
    let addr = coord.addr().to_string();
    let (report, stats) = std::thread::scope(|s| {
        for w in 0..2 {
            let addr = addr.clone();
            let data = &data;
            let opts = &opts;
            s.spawn(move || {
                run_worker(&addr, data.x(), data.labels(), opts)
                    .unwrap_or_else(|e| panic!("worker {w}: {e:#}"))
            });
        }
        coord.run(data.x(), data.labels(), &opts).expect("coordinator")
    });

    let diff = report.model.max_weight_diff(&reference.model);
    assert!(diff < 1e-10, "tcp vs in-process sparse merge: weight diff {diff}");
    assert!((report.model.bias - reference.model.bias).abs() < 1e-10);
    assert_eq!(report.penalty, reference.penalty);
    assert_eq!(report.examples, reference.examples);
    assert_eq!(report.epochs.len(), reference.epochs.len());
    for (a, b) in report.epochs.iter().zip(reference.epochs.iter()) {
        assert!((a.mean_loss - b.mean_loss).abs() < 1e-9, "{} vs {}", a.mean_loss, b.mean_loss);
        assert!((a.objective - b.objective).abs() < 1e-9);
        assert!(a.touched_frac > 0.0, "sparse rounds must report touched fractions");
    }
    assert!(stats.rounds > 0);
    assert!(stats.bytes_per_round() > 0, "sync rounds must ship bytes");
}
