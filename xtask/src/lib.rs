//! The concurrency lint wall (`cargo xtask lint`).
//!
//! Clippy cannot express the repo-specific invariants the lock-free
//! training stack depends on, so this crate enforces them with a
//! comment-aware textual scan over `rust/src/**/*.rs`:
//!
//! * **`std-sync`** — `std::sync` (including `std::sync::atomic`) may
//!   only be named inside the sync facade (`rust/src/sync/`). Everything
//!   else goes through `crate::sync`, so the `--cfg loom` build swaps
//!   every lock/atomic in the crate onto the interleaving explorer at
//!   once — one stray `std::sync::Mutex` would silently escape model
//!   checking.
//! * **`float-partial-cmp`** — no `partial_cmp` outside `rust/src/eval/`.
//!   Sorting floats by `partial_cmp(..).unwrap()` panics on NaN (the
//!   PR 6 bug class); use `f64::total_cmp`. `eval` is exempt because
//!   ranking metrics define their own documented NaN policy.
//! * **`relaxed-ordering`** — `Ordering::Relaxed` only in
//!   `rust/src/train/hogwild.rs` and `rust/src/sync/hogwild_cell.rs`,
//!   the two files whose relaxed accesses carry written memory-ordering
//!   arguments (see `CONCURRENCY.md`). Everywhere else the default is
//!   `SeqCst`: coordination code is never hot enough to justify a
//!   relaxed-ordering proof obligation.
//! * **`serve-unwrap`** — no `.unwrap()` or `.expect(` on the request
//!   paths (`rust/src/serve/` and `rust/src/net/`, each up to its
//!   `#[cfg(test)]` module). A handler panic must degrade to an error
//!   response — and on the binary wire path a panic tears down a whole
//!   training cluster or scoring fan-out, not just one request; use
//!   `crate::sync::lock_ok` / explicit handling.
//! * **`f32-optin`** — the f32 fast-path kernels (`shrink_f32`,
//!   `blocked_score_f32`, `build_f32`) may only be called from files
//!   that mention the `fast_f32` opt-in flag, and the pinned defaults
//!   `fast_f32: false` in `train/options.rs` and `serve/mod.rs` must
//!   stay present — the bitwise-pinned f64 path stays the default.
//! * **`net-deadline`** — every socket acquired on a wire path
//!   (`rust/src/net/` and `rust/src/serve/`, each up to its
//!   `#[cfg(test)]` module) must be armed with explicit timeouts within
//!   a few lines of `TcpStream::connect` / `.accept()` — via
//!   `Deadlines::apply_to`, `set_read_timeout`/`set_write_timeout`, or
//!   the `Channel` deadline setters. An unarmed socket turns a stalled
//!   peer into an unbounded hang; `DISTRIBUTED.md` documents the
//!   liveness policy this rule enforces. Designs that hand the socket
//!   off and arm it elsewhere carry `lint:allow(net-deadline)` naming
//!   where the arming happens.
//!
//! Comments and string-literal contents are blanked before matching, so
//! prose mentioning `std::sync` or `Relaxed` is fine. A specific line
//! can opt out with a `lint:allow(<rule>)` marker anywhere on the line
//! (conventionally in a trailing comment) — use sparingly and say why.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One rule violation at a specific line.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub file: PathBuf,
    /// 1-indexed; 0 for file-level violations (missing pin).
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file.display(), self.line, self.rule, self.message)
    }
}

/// Result of a full lint run.
pub struct Report {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
}

/// Marker that disables one rule on the line it appears on.
fn line_allows(raw_line: &str, rule: &str) -> bool {
    raw_line.find("lint:allow(").is_some_and(|i| {
        raw_line[i + "lint:allow(".len()..]
            .strip_prefix(rule)
            .is_some_and(|rest| rest.starts_with(')'))
    })
}

/// Blank out comments and string-literal contents, preserving line
/// structure (every newline survives) so reported line numbers match
/// the raw file. Handles nested block comments, escapes in string and
/// char literals, raw strings (`r"…"`, `r#"…"#`, `br#"…"#`), and
/// lifetimes (`'a` is not a char literal).
pub fn strip_comments_and_strings(src: &str) -> String {
    enum St {
        Code,
        Line,
        Block(usize),
        Str,
        RawStr(usize),
        Char,
    }
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut st = St::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match st {
            St::Code => {
                if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                    st = St::Line;
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    st = St::Block(1);
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if c == b'"' {
                    // Raw string? Look back over `#`s for an `r`.
                    let mut j = i;
                    let mut hashes = 0;
                    while j > 0 && b[j - 1] == b'#' {
                        j -= 1;
                        hashes += 1;
                    }
                    if j > 0 && b[j - 1] == b'r' {
                        st = St::RawStr(hashes);
                    } else {
                        st = St::Str;
                    }
                    out.push(c);
                    i += 1;
                } else if c == b'\'' {
                    // Char literal vs lifetime: a literal is 'x' or
                    // starts with a backslash escape.
                    let is_escape = i + 1 < b.len() && b[i + 1] == b'\\';
                    let is_plain = i + 2 < b.len() && b[i + 1] != b'\\' && b[i + 2] == b'\'';
                    if is_escape || is_plain {
                        st = St::Char;
                    }
                    out.push(c);
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            St::Line => {
                if c == b'\n' {
                    st = St::Code;
                    out.push(c);
                } else {
                    out.push(b' ');
                }
                i += 1;
            }
            St::Block(depth) => {
                if c == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    st = St::Block(depth + 1);
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else {
                    out.push(if c == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            St::Str => {
                if c == b'\\' && i + 1 < b.len() {
                    out.push(b' ');
                    // A line-continuation escape must keep its newline
                    // so line numbers stay aligned with the raw file.
                    out.push(if b[i + 1] == b'\n' { b'\n' } else { b' ' });
                    i += 2;
                } else if c == b'"' {
                    st = St::Code;
                    out.push(c);
                    i += 1;
                } else {
                    out.push(if c == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == b'"' && b[i + 1..].iter().take_while(|&&x| x == b'#').count() >= hashes {
                    st = St::Code;
                    out.push(c);
                    i += 1 + hashes;
                    for _ in 0..hashes {
                        out.push(b'#');
                    }
                } else {
                    out.push(if c == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            St::Char => {
                if c == b'\\' && i + 1 < b.len() {
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else if c == b'\'' {
                    st = St::Code;
                    out.push(c);
                    i += 1;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
        }
    }
    // Blanked bytes are ASCII; code bytes are copied verbatim.
    String::from_utf8(out).expect("stripping preserves UTF-8")
}

/// Collect every `.rs` file under `dir`, sorted for deterministic output.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rust_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Path relative to the scan root, with forward slashes, for matching
/// against the rule tables.
fn rel_key(root: &Path, file: &Path) -> String {
    file.strip_prefix(root)
        .unwrap_or(file)
        .to_string_lossy()
        .replace('\\', "/")
}

struct NeedleRule {
    name: &'static str,
    needles: &'static [&'static str],
    /// Only scan files whose relative path starts with one of these
    /// prefixes (empty slice = all files).
    scopes: &'static [&'static str],
    /// Skip files whose relative path contains any of these fragments.
    exempt: &'static [&'static str],
    /// Stop scanning a file at its first `#[cfg(test)]` line (test code
    /// is outside the rule's contract).
    stop_at_cfg_test: bool,
    message: &'static str,
}

const NEEDLE_RULES: &[NeedleRule] = &[
    NeedleRule {
        name: "std-sync",
        needles: &["std::sync"],
        scopes: &[],
        exempt: &["sync/"],
        stop_at_cfg_test: false,
        message: "`std::sync` outside the sync facade — import from `crate::sync` so \
                  the loom build model-checks this code (see rust/src/sync/mod.rs)",
    },
    NeedleRule {
        name: "float-partial-cmp",
        needles: &["partial_cmp"],
        scopes: &[],
        exempt: &["eval/"],
        stop_at_cfg_test: false,
        message: "`partial_cmp` on floats panics/misorders on NaN — use `f64::total_cmp` \
                  (ranking code with a documented NaN policy lives in eval/)",
    },
    NeedleRule {
        name: "relaxed-ordering",
        needles: &["Relaxed"],
        scopes: &[],
        exempt: &["train/hogwild.rs", "sync/hogwild_cell.rs"],
        stop_at_cfg_test: false,
        message: "`Ordering::Relaxed` outside the audited hogwild files — use SeqCst, or \
                  move the access behind the documented ψ-stamp argument (CONCURRENCY.md)",
    },
    NeedleRule {
        name: "serve-unwrap",
        needles: &[".unwrap()", ".expect("],
        scopes: &["serve/", "net/"],
        exempt: &[],
        stop_at_cfg_test: true,
        message: "panic on the serving/wire request path — a poisoned lock, bad input, or \
                  malformed frame must degrade to an error response, not tear the process \
                  down (use `crate::sync::lock_ok`, `FrameError`, or match)",
    },
];

/// The f32 fast-path kernels; calls outside their defining modules must
/// sit in a file that names the `fast_f32` opt-in flag.
const F32_CALLS: &[&str] =
    &["shrink_f32(", "blocked_score_f32(", "build_f32(", "save_f32(", "encode_f32("];
const F32_DEFINING: &[&str] = &["optim/lazy.rs", "predict/mod.rs", "model/compact.rs"];
const F32_GUARD: &str = "fast_f32";

/// Files that must keep the f32 fast path off by default, and the
/// literal default they must contain.
const F32_PINS: &[(&str, &str)] = &[
    ("train/options.rs", "fast_f32: false"),
    ("serve/mod.rs", "fast_f32: false"),
];

/// `net-deadline`: wire paths where every acquired socket must be armed.
const DEADLINE_SCOPES: &[&str] = &["net/", "serve/"];
/// Socket-acquisition sites the rule keys on.
const DEADLINE_ACQUIRE: &[&str] = &["TcpStream::connect", ".accept()"];
/// Any of these within the window counts as arming the socket.
const DEADLINE_ARMS: &[&str] = &[
    "set_read_timeout",
    "set_write_timeout",
    ".apply_to(",
    "set_deadlines(",
    "set_read_deadline(",
];
/// Lines after the acquisition (inclusive of it) the arming may sit in.
const DEADLINE_WINDOW: usize = 8;

/// Run every rule over `<repo_root>/rust/src`.
pub fn run_lints(repo_root: &Path) -> io::Result<Report> {
    let src_root = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    rust_files(&src_root, &mut files)?;

    let mut violations = Vec::new();
    let mut pins_seen = vec![false; F32_PINS.len()];

    for file in &files {
        let rel = rel_key(&src_root, file);
        let raw = fs::read_to_string(file)?;
        let stripped = strip_comments_and_strings(&raw);
        let raw_lines: Vec<&str> = raw.lines().collect();

        for rule in NEEDLE_RULES {
            if !(rule.scopes.is_empty() || rule.scopes.iter().any(|s| rel.starts_with(s))) {
                continue;
            }
            if rule.exempt.iter().any(|e| rel.contains(e)) {
                continue;
            }
            for (idx, line) in stripped.lines().enumerate() {
                if rule.stop_at_cfg_test && line.contains("#[cfg(test)]") {
                    break;
                }
                if let Some(needle) = rule.needles.iter().find(|n| line.contains(**n)) {
                    let raw_line = raw_lines.get(idx).copied().unwrap_or("");
                    if line_allows(raw_line, rule.name) {
                        continue;
                    }
                    violations.push(Violation {
                        rule: rule.name,
                        file: file.clone(),
                        line: idx + 1,
                        message: format!("`{}`: {}", needle, rule.message),
                    });
                }
            }
        }

        // f32-optin, part 1: gated use.
        if !F32_DEFINING.iter().any(|d| rel.ends_with(d)) {
            for (idx, line) in stripped.lines().enumerate() {
                if let Some(needle) = F32_CALLS.iter().find(|n| line.contains(**n)) {
                    let raw_line = raw_lines.get(idx).copied().unwrap_or("");
                    if line_allows(raw_line, "f32-optin") {
                        continue;
                    }
                    if !stripped.contains(F32_GUARD) {
                        violations.push(Violation {
                            rule: "f32-optin",
                            file: file.clone(),
                            line: idx + 1,
                            message: format!(
                                "`{}` called in a file that never names the `{}` opt-in flag — \
                                 the f32 fast path must stay behind the per-call opt-in",
                                needle, F32_GUARD
                            ),
                        });
                    }
                }
            }
        }

        // f32-optin, part 2: record which pins are present.
        for (i, (pin_file, pin)) in F32_PINS.iter().enumerate() {
            if rel.ends_with(pin_file) && stripped.contains(pin) {
                pins_seen[i] = true;
            }
        }

        // net-deadline: every socket acquired on a wire path is armed
        // with timeouts near the acquisition site (test modules are
        // outside the contract, like serve-unwrap).
        if DEADLINE_SCOPES.iter().any(|s| rel.starts_with(s)) {
            let lines: Vec<&str> = stripped.lines().collect();
            for (idx, line) in lines.iter().enumerate() {
                if line.contains("#[cfg(test)]") {
                    break;
                }
                let Some(needle) = DEADLINE_ACQUIRE.iter().find(|n| line.contains(**n)) else {
                    continue;
                };
                let raw_line = raw_lines.get(idx).copied().unwrap_or("");
                if line_allows(raw_line, "net-deadline") {
                    continue;
                }
                let end = lines.len().min(idx + 1 + DEADLINE_WINDOW);
                if lines[idx..end].iter().any(|l| DEADLINE_ARMS.iter().any(|a| l.contains(*a))) {
                    continue;
                }
                violations.push(Violation {
                    rule: "net-deadline",
                    file: file.clone(),
                    line: idx + 1,
                    message: format!(
                        "`{}` with no read/write deadline within {} lines — an unarmed wire \
                         socket turns a stalled peer into an unbounded hang; arm it with \
                         `Deadlines::apply_to` / `set_read_timeout` + `set_write_timeout` / \
                         the `Channel` deadline setters, or carry `lint:allow(net-deadline)` \
                         naming where it is armed",
                        needle, DEADLINE_WINDOW
                    ),
                });
            }
        }
    }

    for (i, (pin_file, pin)) in F32_PINS.iter().enumerate() {
        if !pins_seen[i] {
            violations.push(Violation {
                rule: "f32-optin",
                file: src_root.join(pin_file),
                line: 0,
                message: format!(
                    "pinned default `{}` not found — the f64 path must stay the default \
                     (if the struct moved, update F32_PINS in xtask/src/lib.rs)",
                    pin
                ),
            });
        }
    }

    violations.sort_by(|a, b| {
        a.file.cmp(&b.file).then_with(|| a.line.cmp(&b.line)).then_with(|| a.rule.cmp(b.rule))
    });
    Ok(Report { violations, files_scanned: files.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripping_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = 1; // std::sync here\nlet s = \"Relaxed\";\n/* partial_cmp\nspans */ let b = 2;\n";
        let out = strip_comments_and_strings(src);
        assert_eq!(out.lines().count(), src.lines().count());
        assert!(!out.contains("std::sync"));
        assert!(!out.contains("Relaxed"));
        assert!(!out.contains("partial_cmp"));
        assert!(out.contains("let a = 1;"));
        assert!(out.contains("let b = 2;"));
    }

    #[test]
    fn stripping_handles_nested_blocks_raw_strings_chars_and_lifetimes() {
        let src = "/* a /* nested */ still */ keep1\nlet r = r#\"std::sync\"#;\nlet c = '\\'';\nfn f<'a>(x: &'a u32) -> &'a u32 { x } // keep2 in comment\n";
        let out = strip_comments_and_strings(src);
        assert!(out.contains("keep1"));
        assert!(!out.contains("std::sync"));
        assert!(out.contains("fn f<'a>(x: &'a u32) -> &'a u32 { x }"));
        assert!(!out.contains("keep2"));
    }

    #[test]
    fn escape_marker_is_rule_specific() {
        assert!(line_allows("use std::sync::Arc; // lint:allow(std-sync): bootstrap", "std-sync"));
        assert!(!line_allows("use std::sync::Arc; // lint:allow(std-sync)", "relaxed-ordering"));
        assert!(!line_allows("use std::sync::Arc;", "std-sync"));
    }
}
