//! `cargo xtask lint` — run the concurrency lint wall over `rust/src`.
//!
//! Exit status: 0 when clean, 1 when any rule fires, 2 on usage/IO
//! errors. CI runs this next to `cargo fmt --check` and clippy; the
//! rules themselves are documented in [`xtask`] (src/lib.rs) and
//! `CONCURRENCY.md`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: cargo xtask lint [--root <repo-root>]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {}
        _ => return usage(),
    }
    // Default root: the workspace directory containing this crate —
    // correct both locally and in CI regardless of invocation cwd.
    let mut root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root")
        .to_path_buf();
    while let Some(flag) = args.next() {
        match flag.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => return usage(),
            },
            _ => return usage(),
        }
    }

    match xtask::run_lints(&root) {
        Ok(report) => {
            for v in &report.violations {
                eprintln!("{v}");
            }
            if report.violations.is_empty() {
                println!("xtask lint: clean ({} files)", report.files_scanned);
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "xtask lint: {} violation(s) in {} files scanned",
                    report.violations.len(),
                    report.files_scanned
                );
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask lint: cannot scan {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
