//! The lint wall against its fixtures — and against the real tree.
//!
//! `fixtures/bad` seeds one violation per rule (plus comment/string
//! decoys that must NOT fire); `fixtures/clean` holds the sanctioned
//! idioms for the same shapes. The last test runs the scanner over the
//! actual repository, so `cargo test` fails the moment the tree regresses
//! on any rule — CI runs `cargo xtask lint` separately for a readable
//! report.

use std::path::{Path, PathBuf};

use xtask::run_lints;

fn fixture(which: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(which)
}

#[test]
fn bad_fixture_trips_every_rule_exactly_where_seeded() {
    let report = run_lints(&fixture("bad")).expect("fixture scan");
    let hits: Vec<(String, &'static str, usize)> = report
        .violations
        .iter()
        .map(|v| {
            let file = v.file.file_name().unwrap().to_string_lossy().into_owned();
            (file, v.rule, v.line)
        })
        .collect();

    // worker.rs: two std::sync imports, one Relaxed, one partial_cmp,
    // one ungated f32 kernel call. The std::sync in a comment (line 4)
    // must not appear.
    assert!(hits.contains(&("worker.rs".into(), "std-sync", 5)), "{hits:?}");
    assert!(hits.contains(&("worker.rs".into(), "std-sync", 6)), "{hits:?}");
    assert!(!hits.contains(&("worker.rs".into(), "std-sync", 4)), "comment fired: {hits:?}");
    assert!(hits.contains(&("worker.rs".into(), "relaxed-ordering", 9)), "{hits:?}");
    assert!(hits.contains(&("worker.rs".into(), "float-partial-cmp", 13)), "{hits:?}");
    assert!(hits.contains(&("worker.rs".into(), "f32-optin", 18)), "{hits:?}");

    // serve/mod.rs: the request-path unwrap, not the test-module one.
    let serve_unwraps: Vec<usize> = hits
        .iter()
        .filter(|(f, r, _)| f == "mod.rs" && *r == "serve-unwrap")
        .map(|&(_, _, l)| l)
        .collect();
    assert_eq!(serve_unwraps, vec![6], "exactly the pre-#[cfg(test)] unwrap: {hits:?}");

    // net/frame.rs: the wire-path `.expect(`, not the test-module unwrap.
    let net_panics: Vec<usize> = hits
        .iter()
        .filter(|(f, r, _)| f == "frame.rs" && *r == "serve-unwrap")
        .map(|&(_, _, l)| l)
        .collect();
    assert_eq!(net_panics, vec![5], "exactly the pre-#[cfg(test)] expect: {hits:?}");

    // net/cluster.rs: the unarmed connect; the `.accept()` in the
    // module comment is a decoy that must not fire.
    let deadlines: Vec<usize> = hits
        .iter()
        .filter(|(f, r, _)| f == "cluster.rs" && *r == "net-deadline")
        .map(|&(_, _, l)| l)
        .collect();
    assert_eq!(deadlines, vec![8], "exactly the unarmed connect: {hits:?}");

    // Both pinned defaults are missing/flipped (line 0 = file-level).
    let pin_files: Vec<&str> = hits
        .iter()
        .filter(|(_, r, l)| *r == "f32-optin" && *l == 0)
        .map(|(f, _, _)| f.as_str())
        .collect();
    assert_eq!(pin_files, vec!["mod.rs", "options.rs"], "{hits:?}");

    assert_eq!(report.violations.len(), 10, "no extra violations: {hits:?}");
}

#[test]
fn clean_fixture_passes_including_escape_marker_and_gated_f32() {
    let report = run_lints(&fixture("clean")).expect("fixture scan");
    assert!(
        report.violations.is_empty(),
        "clean fixture must pass: {:?}",
        report.violations
    );
    assert_eq!(report.files_scanned, 5);
}

#[test]
fn the_real_tree_is_clean() {
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root");
    let report = run_lints(repo_root).expect("repo scan");
    assert!(
        report.violations.is_empty(),
        "rust/src regressed on the lint wall:\n{}",
        report
            .violations
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 30, "scanner found only {} files", report.files_scanned);
}
