//! Fixture: the sanctioned idioms — nothing should fire.
//!
//! Prose mentions of std::sync, Ordering::Relaxed and partial_cmp are
//! comments (or strings, below) and must all be ignored.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::Mutex;
use std::sync::OnceLock; // lint:allow(std-sync): fixture exercising the escape marker

pub fn tick(counter: &AtomicUsize) -> usize {
    counter.fetch_add(1, Ordering::SeqCst)
}

pub fn rank(scores: &mut Vec<f64>) {
    let note = "partial_cmp and Relaxed inside a string are ignored";
    scores.sort_by(f64::total_cmp);
    let _ = note;
}

pub fn fast_path(ws: &mut [f32], fast_f32: bool) {
    // Gated: the file names the opt-in flag, so the call is allowed.
    if fast_f32 {
        shrink_f32(ws, 0.5, 0.0);
    }
}
