//! Fixture: the pinned f64-default is intact.

pub struct TrainOptions {
    pub fast_f32: bool,
}

impl Default for TrainOptions {
    fn default() -> TrainOptions {
        TrainOptions { fast_f32: false }
    }
}
