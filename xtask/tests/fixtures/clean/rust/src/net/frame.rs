//! Fixture: the wire path returns structured errors instead of
//! panicking; unwraps only inside the test module.

pub fn read_header(buf: &[u8]) -> Result<u32, &'static str> {
    match buf.get(..4) {
        Some(b) => Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]])),
        None => Err("truncated header"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
