//! Fixture: wire sockets armed at the acquisition site, and the
//! sanctioned escape for handoff designs that arm in the handler.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

pub fn dial(addr: &str) -> std::io::Result<TcpStream> {
    let stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    Ok(stream)
}

pub fn next_conn(listener: &TcpListener) -> std::io::Result<TcpStream> {
    let (stream, _) = listener.accept()?; // lint:allow(net-deadline): armed by the pool handler after the queue handoff
    Ok(stream)
}
