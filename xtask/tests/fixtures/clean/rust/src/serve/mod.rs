//! Fixture: poison-tolerant locking on the request path, unwraps only
//! inside the test module, pinned default present.

pub struct ServeOptions {
    pub fast_f32: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { fast_f32: false }
    }
}

pub fn handle(line: &str) -> f64 {
    let stats = crate::sync::lock_ok(STATS.lock());
    stats.score(line)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
