//! Fixture: an `.unwrap()` on the request path (before the test
//! module) must fire; the one inside `#[cfg(test)]` must not. The
//! `fast_f32: false` pin is also missing from this file.

pub fn handle(line: &str) -> f64 {
    let stats = STATS.lock().unwrap();
    stats.score(line)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
