//! Seeded-violation fixture: every needle rule should fire here.
//! (Not compiled — scanned by xtask/tests/lint_fixtures.rs.)

// A comment naming std::sync must NOT fire; only the code below does.
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

pub fn tick(counter: &AtomicUsize) -> usize {
    counter.fetch_add(1, Ordering::Relaxed)
}

pub fn rank(scores: &mut Vec<f64>) {
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn fast_path(ws: &mut [f32]) {
    // Calls the f32 kernel but the file never names the opt-in flag.
    shrink_f32(ws, 0.5, 0.0);
}
