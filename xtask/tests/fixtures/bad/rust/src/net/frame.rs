//! Fixture: an `.expect(` on the wire path (before the test module)
//! must fire serve-unwrap; the unwrap inside `#[cfg(test)]` must not.

pub fn read_header(buf: &[u8]) -> u32 {
    let bytes: [u8; 4] = buf[..4].try_into().expect("short header");
    u32::from_le_bytes(bytes)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
