//! Fixture: a wire socket acquired and used with no deadline anywhere
//! near it — the `.accept()` named in this comment is a decoy.

use std::io::Write;
use std::net::TcpStream;

pub fn dial(addr: &str) -> std::io::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(b"hello")?;
    let greeting = [0u8; 4];
    let nonce = u32::from_le_bytes(greeting);
    let frame = nonce.to_le_bytes();
    stream.write_all(&frame)?;
    stream.write_all(&frame)?;
    stream.write_all(&frame)?;
    stream.write_all(&frame)?;
    Ok(stream)
}
