//! Fixture: the pinned default was flipped — `f32-optin` must fire.

pub struct TrainOptions {
    pub fast_f32: bool,
}

impl Default for TrainOptions {
    fn default() -> TrainOptions {
        TrainOptions { fast_f32: true }
    }
}
